//! Minimal JSON parser/serializer (substrate — no serde in the offline
//! vendor set).  Supports the full JSON grammar; numbers are f64.
//!
//! Used for: `artifacts/manifest.json`, run configs, searched-model dumps
//! and experiment reports.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are ordered (BTreeMap) so serialization is
/// deterministic — reports diff cleanly across runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Object member lookup that errors with the key name (manifest parsing).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- builders ---------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            cp = cp * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut lo = 0u32;
                            for _ in 0..4 {
                                let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                lo = lo * 16
                                    + (d as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            cp = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                        }
                        s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 from the source slice.
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + width;
                    let chunk = self
                        .b
                        .get(start..start + width)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), 1.0);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // Surrogate pair: U+1F600
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
        // Raw multi-byte passthrough.
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"n":-3,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
