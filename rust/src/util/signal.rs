//! Minimal POSIX signal plumbing (no `libc`/`signal-hook` crates in the
//! offline vendor set — `std` already links the platform libc, so the two
//! symbols we need are declared by hand).
//!
//! Two consumers:
//!   * `autoq serve` installs a **shutdown flag**: SIGINT/SIGTERM flip one
//!     process-global atomic that the accept loop polls, so the daemon
//!     drains in-flight jobs and exits cleanly instead of dying mid-job.
//!   * `autoq worker` **ignores** SIGINT/SIGTERM: a Ctrl-C delivered to the
//!     foreground process group must stop the *parent* gracefully, not rip
//!     the shard workers out from under its drain — workers exit on stdin
//!     EOF / an `exit` frame, which the parent's `ShardClient::drop` always
//!     sends (that, not signals, is the no-orphan contract).
//!
//! Only async-signal-safe work happens in the handler (one atomic store).

use std::sync::atomic::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// True once SIGINT/SIGTERM arrived after [`install_shutdown_flag`].
pub fn shutdown_requested() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}

/// Test/daemon hook: trip the flag as if a signal had arrived.
pub fn request_shutdown() {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
mod imp {
    use super::SHUTDOWN;
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    const SIG_IGN: usize = 1;

    extern "C" {
        /// POSIX `signal(2)`.  The handler travels as a `usize` because it
        /// is either `SIG_IGN` or a function address; `std` links libc, so
        /// no new dependency is introduced.
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_terminate(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    pub fn install_shutdown_flag() {
        unsafe {
            signal(SIGINT, on_terminate as usize);
            signal(SIGTERM, on_terminate as usize);
        }
    }

    pub fn ignore_termination() {
        unsafe {
            signal(SIGINT, SIG_IGN);
            signal(SIGTERM, SIG_IGN);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install_shutdown_flag() {}
    pub fn ignore_termination() {}
}

/// Route SIGINT/SIGTERM into [`shutdown_requested`] (daemon entry point).
pub fn install_shutdown_flag() {
    imp::install_shutdown_flag()
}

/// Ignore SIGINT/SIGTERM entirely (shard worker entry point).
pub fn ignore_termination() {
    imp::ignore_termination()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_starts_clear_and_latches() {
        // Cannot assert the initial state: another test in this binary may
        // have tripped the process-global flag already.
        request_shutdown();
        assert!(shutdown_requested());
    }

    #[cfg(unix)]
    #[test]
    fn handlers_install_without_crashing() {
        install_shutdown_flag();
        ignore_termination();
        // Restore default-ish behavior for the rest of the test binary.
        install_shutdown_flag();
    }
}
