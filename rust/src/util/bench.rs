//! Micro-benchmark harness (criterion is not in the offline vendor set).
//! Used by the `cargo bench` targets (`benches/*.rs`, `harness = false`).

use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>6} iters  mean {:>12}  min {:>12}  ±{:>10}",
            self.name,
            self.iters,
            human_time(self.mean_s),
            human_time(self.min_s),
            human_time(self.stddev_s),
        )
    }
}

pub fn human_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Run `f` `iters` times (after `warmup` unmeasured runs) and report.
pub fn bench<F: FnMut() -> R, R>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / iters as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(0.0, f64::max),
        stddev_s: var.sqrt(),
    };
    println!("{}", r.row());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop", 1, 10, || 1 + 1);
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.min_s <= r.mean_s);
    }

    #[test]
    fn humanized_times() {
        assert!(human_time(2.5e-9).ends_with("ns"));
        assert!(human_time(2.5e-5).ends_with("µs"));
        assert!(human_time(2.5e-2).ends_with("ms"));
        assert!(human_time(2.5).ends_with("s"));
    }
}
