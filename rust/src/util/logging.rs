//! Leveled stderr logger with wall-clock-relative timestamps.
//!
//! `AUTOQ_LOG` in {trace, debug, info, warn, error}; default info.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("AUTOQ_LOG") {
        let lvl = match v.to_lowercase().as_str() {
            "trace" => Level::Trace,
            "debug" => Level::Debug,
            "warn" => Level::Warn,
            "error" => Level::Error,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    }
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Trace => "TRACE",
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($a)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($a)*)) };
}
#[macro_export]
macro_rules! warn_ {
    ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($a)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
