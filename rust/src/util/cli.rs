//! Tiny CLI argument parser substrate (no clap in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args, with
//! typed getters and a generated `--help`.

use std::collections::BTreeMap;
use std::fmt;

/// Marker error: the invocation itself is wrong (unknown option/command,
/// missing value, unparsable number).  `main` downcasts to this to exit
/// with code 2, distinguishing caller mistakes from job failures (code 1).
#[derive(Debug)]
pub struct UsageError(pub String);

impl fmt::Display for UsageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for UsageError {}

/// Marker error: the user asked for `--help`; carries the usage text and
/// exits 0 — help is not a failure.
#[derive(Debug)]
pub struct HelpRequested(pub String);

impl fmt::Display for HelpRequested {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for HelpRequested {}

fn usage_err(msg: String) -> anyhow::Error {
    anyhow::Error::new(UsageError(msg))
}

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Declarative argument set for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    pub cmd: String,
    specs: Vec<ArgSpec>,
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn new(cmd: &str) -> Self {
        Args { cmd: cmd.to_string(), ..Default::default() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("usage: autoq {} [options]\n\noptions:\n", self.cmd);
        for spec in &self.specs {
            let d = spec
                .default
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<20} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse raw args (after the subcommand).  Unknown `--keys` are
    /// [`UsageError`]s (exit 2); `--help` is a [`HelpRequested`] (exit 0).
    pub fn parse(mut self, raw: &[String]) -> anyhow::Result<Self> {
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                return Err(anyhow::Error::new(HelpRequested(self.usage())));
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| usage_err(format!("unknown option --{key}\n{}", self.usage())))?
                    .clone();
                let val = if spec.is_flag {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    raw.get(i)
                        .ok_or_else(|| usage_err(format!("--{key} needs a value")))?
                        .clone()
                };
                self.values.insert(key, val);
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    fn raw(&self, name: &str) -> Option<String> {
        self.values.get(name).cloned().or_else(|| {
            self.specs
                .iter()
                .find(|s| s.name == name)
                .and_then(|s| s.default.map(str::to_string))
        })
    }

    pub fn get(&self, name: &str) -> String {
        self.raw(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }
    pub fn get_usize(&self, name: &str) -> anyhow::Result<usize> {
        let v = self.get(name);
        v.parse()
            .map_err(|_| usage_err(format!("--{name} expects an integer, got {v:?}")))
    }
    pub fn get_f64(&self, name: &str) -> anyhow::Result<f64> {
        let v = self.get(name);
        v.parse()
            .map_err(|_| usage_err(format!("--{name} expects a number, got {v:?}")))
    }
    pub fn get_u64(&self, name: &str) -> anyhow::Result<u64> {
        let v = self.get(name);
        v.parse()
            .map_err(|_| usage_err(format!("--{name} expects an integer, got {v:?}")))
    }
    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.raw(name).as_deref(), Some("true" | "1" | "yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::new("t")
            .opt("model", "cif10", "model name")
            .opt("episodes", "400", "episode count")
            .flag("paper-scale", "full scale")
            .parse(&v(&["--model", "res18", "--paper-scale", "--episodes=10"]))
            .unwrap();
        assert_eq!(a.get("model"), "res18");
        assert_eq!(a.get_usize("episodes").unwrap(), 10);
        assert!(a.get_bool("paper-scale"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::new("t")
            .opt("model", "cif10", "")
            .flag("fast", "")
            .parse(&v(&[]))
            .unwrap();
        assert_eq!(a.get("model"), "cif10");
        assert!(!a.get_bool("fast"));
    }

    #[test]
    fn unknown_option_is_a_usage_error() {
        let err = Args::new("t").parse(&v(&["--nope", "1"])).unwrap_err();
        assert!(err.downcast_ref::<UsageError>().is_some());
    }

    #[test]
    fn help_is_not_a_usage_error() {
        let err = Args::new("t").opt("x", "1", "").parse(&v(&["--help"])).unwrap_err();
        assert!(err.downcast_ref::<HelpRequested>().is_some());
        assert!(err.downcast_ref::<UsageError>().is_none());
        assert!(format!("{err}").contains("--x"));
    }

    #[test]
    fn bad_number_is_a_usage_error() {
        let a = Args::new("t").opt("n", "1", "").parse(&v(&["--n", "abc"])).unwrap();
        assert!(a.get_usize("n").unwrap_err().downcast_ref::<UsageError>().is_some());
    }

    #[test]
    fn positional_collected() {
        let a = Args::new("t").parse(&v(&["x", "y"])).unwrap();
        assert_eq!(a.positional, vec!["x", "y"]);
    }
}
