//! Property-based testing substrate (proptest is not in the offline vendor
//! set).  Seeded generators + a `forall` runner with failure-case reporting
//! and greedy input shrinking for `Vec`-valued cases.
//!
//! Used across the coordinator tests: routing/batching/state invariants of
//! the search loop, cost-model monotonicity, replay-buffer safety,
//! bit-config packing round-trips, FPGA-simulator conservation laws.

use crate::util::rng::Rng;

/// Number of cases per property (tunable via AUTOQ_PROP_CASES).
pub fn cases() -> usize {
    std::env::var("AUTOQ_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// Run `prop` on `n` random inputs drawn by `gen`.  On failure, tries to
/// shrink via `shrink` (smaller variants first) and panics with the minimal
/// failing input's debug form.
pub fn forall<T, G, P, S>(seed: u64, mut gen: G, mut prop: P, shrink: S)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases() {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink loop.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut budget = 200usize;
            while improved && budget > 0 {
                improved = false;
                for cand in shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                    if budget == 0 {
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// `forall` without shrinking.
pub fn forall_ns<T, G, P>(seed: u64, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    forall(seed, gen, prop, |_| Vec::new());
}

/// Standard shrinker for vectors: drop halves, drop single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 16 {
        for i in 0..v.len() {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    out
}

/// Generator helpers.
///
/// Bit-width vectors respect a minimum of 1: width 0 means "channel
/// pruned" and is rejected by config validation (e.g. network-granularity
/// bits must be in 1..=32), so properties that exercise pruning must
/// inject zeros deliberately rather than receive them at random.
pub fn gen_bits_vec(rng: &mut Rng, max_len: usize, max_bits: u32) -> Vec<u8> {
    let n = 1 + rng.below(max_len.max(1));
    (0..n).map(|_| 1 + rng.below(max_bits.max(1) as usize) as u8).collect()
}

pub fn gen_f32_vec(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
    let n = 1 + rng.below(max_len.max(1));
    (0..n).map(|_| (rng.normal() as f32) * scale).collect()
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall_ns(
            1,
            |r| r.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, cases());
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall_ns(2, |r| r.below(10), |&x| {
            if x < 5 {
                Ok(())
            } else {
                Err(format!("{x} >= 5"))
            }
        });
    }

    #[test]
    fn shrinking_finds_smaller_case() {
        // Property: no vector contains a value >= 50.  The shrinker should
        // reduce any failing vector to length 1.
        let result = std::panic::catch_unwind(|| {
            forall(
                3,
                |r| {
                    let n = 1 + r.below(20);
                    (0..n).map(|_| r.below(100) as u32).collect::<Vec<u32>>()
                },
                |v| {
                    if v.iter().all(|&x| x < 50) {
                        Ok(())
                    } else {
                        Err("contains big".into())
                    }
                },
                |v| shrink_vec(v),
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The minimal counterexample is a single-element vector.
        assert!(msg.contains("input: ["), "{msg}");
        let inside = msg.split("input: [").nth(1).unwrap();
        let list = inside.split(']').next().unwrap();
        assert_eq!(list.split(',').count(), 1, "not shrunk: {msg}");
    }

    #[test]
    fn gen_helpers_in_range() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            let b = gen_bits_vec(&mut r, 32, 8);
            assert!(!b.is_empty() && b.len() <= 32);
            assert!(b.iter().all(|&x| x <= 8));
        }
    }

    /// Regression: bit-width generators must never emit 0-bit entries —
    /// 0 means "pruned" and config validation rejects it as a searched
    /// network-granularity width.
    #[test]
    fn gen_bits_vec_respects_min_width_one() {
        let mut r = Rng::new(99);
        for _ in 0..2000 {
            let b = gen_bits_vec(&mut r, 16, 32);
            assert!(b.iter().all(|&x| (1..=32).contains(&x)), "{b:?}");
        }
        // Degenerate max_bits still yields width-1 entries, not zeros.
        for _ in 0..50 {
            let b = gen_bits_vec(&mut r, 4, 0);
            assert!(b.iter().all(|&x| x == 1), "{b:?}");
        }
    }
}
