//! Deterministic RNG substrate: xoshiro256++ with Gaussian sampling.
//!
//! Every stochastic component of the system (exploration noise, replay
//! sampling, synthetic data, weight init, goal relabeling candidates) takes
//! an explicit `Rng` so whole searches replay bit-identically from a seed —
//! the property Fig. 8's 10-run averages and all tests rely on.

/// xoshiro256++ PRNG (Blackman & Vigna).  Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    spare: Option<f64>,
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97f4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let s = [
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (stable across runs) — used to give each
    /// subsystem (agent noise, replay, data) its own seed from a master seed.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97f4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).  n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift (Lemire); tiny modulo bias is
        // irrelevant for replay sampling but we keep it unbiased anyway.
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n as u64 {
            let t = (u64::MAX - n as u64 + 1) % n as u64;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill a slice with N(0, sigma) f32s (weight init, noise vectors).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = (self.normal() as f32) * sigma;
        }
    }

    /// Full generator state for byte-exact checkpointing: the four
    /// xoshiro words plus the cached Box-Muller spare as raw f64 bits.
    /// `restore` on the returned values resumes the exact stream.
    pub fn state(&self) -> ([u64; 4], Option<u64>) {
        (self.s, self.spare.map(f64::to_bits))
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn restore(s: [u64; 4], spare_bits: Option<u64>) -> Rng {
        Rng { s, spare: spare_bits.map(f64::from_bits) }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_restore_resumes_exact_stream() {
        let mut r = Rng::new(123);
        // Burn a normal() so the Box-Muller spare is populated.
        r.normal();
        let (s, spare) = r.state();
        assert!(spare.is_some());
        let mut restored = Rng::restore(s, spare);
        for _ in 0..64 {
            assert_eq!(r.normal().to_bits(), restored.normal().to_bits());
            assert_eq!(r.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut master = Rng::new(9);
        let mut a = master.fork(1);
        let mut b = master.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
