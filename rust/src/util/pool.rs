//! Deterministic fan-out worker pool and the `--threads` resolution rules
//! (rayon is not in the offline vendor set).
//!
//! [`WorkerPool::run_indexed`] maps an index range through a job closure
//! on scoped worker threads, handing out indices from a shared atomic
//! cursor and returning results **in index order** — scheduling decides
//! only *who* computes an index, never the value or the reduction order,
//! so a pure-per-index job gives byte-identical output at every thread
//! count.  The pool object itself is persistent (owned by the reference
//! backend and shared into its executables); worker threads are scoped to
//! each fan-out, which keeps every borrow compiler-checked and costs
//! microseconds against batch evaluations measured in milliseconds.
//!
//! [`Parallelism`] mirrors `BackendKind` selection: explicit caller choice
//! (`--threads` / `open_with_opts`) > `$AUTOQ_THREADS` > auto (all
//! available cores).

use std::sync::atomic::{AtomicUsize, Ordering};

/// A resolved worker-thread count (≥ 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism(usize);

impl Parallelism {
    pub fn new(threads: usize) -> Parallelism {
        Parallelism(threads.max(1))
    }

    pub fn get(self) -> usize {
        self.0
    }

    /// All available cores (1 if the OS won't say).
    pub fn auto() -> Parallelism {
        Parallelism::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    /// Parse an optional CLI value: empty, `auto` or `0` mean
    /// "auto-resolve".  The single parser behind every `--threads` flag.
    pub fn parse_opt(s: &str) -> anyhow::Result<Option<Parallelism>> {
        let t = s.trim().to_ascii_lowercase();
        if t.is_empty() || t == "auto" || t == "0" {
            return Ok(None);
        }
        let n: usize = t
            .parse()
            .map_err(|_| anyhow::anyhow!("expected a thread count or 'auto', got {s:?}"))?;
        Ok(Some(Parallelism::new(n)))
    }

    /// `$AUTOQ_THREADS`, if set and non-empty (`auto`/`0` count as unset).
    pub fn from_env() -> anyhow::Result<Option<Parallelism>> {
        match std::env::var("AUTOQ_THREADS") {
            Ok(s) if !s.trim().is_empty() => Self::parse_opt(&s),
            _ => Ok(None),
        }
    }

    /// Resolve a thread count: explicit choice beats `$AUTOQ_THREADS`
    /// beats auto (all cores).
    pub fn resolve(explicit: Option<Parallelism>) -> anyhow::Result<Parallelism> {
        if let Some(p) = explicit {
            return Ok(p);
        }
        if let Some(p) = Self::from_env()? {
            return Ok(p);
        }
        Ok(Self::auto())
    }
}

/// Fan-out pool with a fixed thread budget and deterministic reduction
/// order (see module docs).
#[derive(Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `0..n` through `f`, results in index order.  Runs serially
    /// when the budget (or `n`) is 1 — that path is the exact loop a
    /// pool-free caller would write, so thread count never changes
    /// results for pure-per-index jobs.  Panics in `f` propagate.
    pub fn run_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let workers = self.threads.min(n);
        if workers <= 1 {
            return (0..n).map(f).collect();
        }
        let cursor = AtomicUsize::new(0);
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let cursor = &cursor;
                    let f = &f;
                    s.spawn(move || {
                        let mut got: Vec<(usize, R)> = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            got.push((i, f(i)));
                        }
                        got
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("worker pool job panicked") {
                    slots[i] = Some(r);
                }
            }
        });
        slots.into_iter().map(|r| r.expect("cursor covered every index")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_index_order_at_any_width() {
        for threads in [1usize, 2, 3, 8] {
            let pool = WorkerPool::new(threads);
            let out = pool.run_indexed(17, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_oversized_budgets() {
        let pool = WorkerPool::new(8);
        assert!(pool.run_indexed(0, |i| i).is_empty());
        assert_eq!(pool.run_indexed(1, |i| i + 1), vec![1]);
        assert_eq!(WorkerPool::new(0).threads(), 1, "budget clamps to 1");
    }

    #[test]
    fn fallible_jobs_compose_with_results() {
        let pool = WorkerPool::new(4);
        let out: anyhow::Result<Vec<usize>> =
            pool.run_indexed(9, |i| anyhow::Ok(i * 2)).into_iter().collect();
        assert_eq!(out.unwrap(), (0..9).map(|i| i * 2).collect::<Vec<_>>());
        let bad: anyhow::Result<Vec<usize>> = pool
            .run_indexed(9, |i| if i == 5 { anyhow::bail!("boom") } else { Ok(i) })
            .into_iter()
            .collect();
        assert!(bad.is_err());
    }

    #[test]
    fn parallelism_parse_and_clamp() {
        assert_eq!(Parallelism::parse_opt("").unwrap(), None);
        assert_eq!(Parallelism::parse_opt("auto").unwrap(), None);
        assert_eq!(Parallelism::parse_opt("0").unwrap(), None);
        assert_eq!(Parallelism::parse_opt("4").unwrap(), Some(Parallelism::new(4)));
        assert!(Parallelism::parse_opt("four").is_err());
        assert_eq!(Parallelism::new(0).get(), 1);
        assert!(Parallelism::auto().get() >= 1);
        assert_eq!(Parallelism::resolve(Some(Parallelism::new(3))).unwrap().get(), 3);
    }
}
