//! Deterministic fan-out worker pool and the `--threads` resolution rules
//! (rayon is not in the offline vendor set).
//!
//! [`WorkerPool::run_indexed`] maps an index range through a job closure
//! on scoped worker threads, handing out indices from a shared atomic
//! cursor and returning results **in index order** — scheduling decides
//! only *who* computes an index, never the value or the reduction order,
//! so a pure-per-index job gives byte-identical output at every thread
//! count.  The pool object itself is persistent (owned by the reference
//! backend and shared into its executables); worker threads are scoped to
//! each fan-out, which keeps every borrow compiler-checked and costs
//! microseconds against batch evaluations measured in milliseconds.
//!
//! [`Parallelism`] mirrors `BackendKind` selection: explicit caller choice
//! (`--threads` / `open_with_opts`) > `$AUTOQ_THREADS` > auto (all
//! available cores).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A resolved worker-thread count (≥ 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Parallelism(usize);

impl Parallelism {
    pub fn new(threads: usize) -> Parallelism {
        Parallelism(threads.max(1))
    }

    pub fn get(self) -> usize {
        self.0
    }

    /// All available cores (1 if the OS won't say).
    pub fn auto() -> Parallelism {
        Parallelism::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    }

    /// Parse an optional CLI value: empty, `auto` or `0` mean
    /// "auto-resolve".  The single parser behind every `--threads` flag.
    pub fn parse_opt(s: &str) -> anyhow::Result<Option<Parallelism>> {
        let t = s.trim().to_ascii_lowercase();
        if t.is_empty() || t == "auto" || t == "0" {
            return Ok(None);
        }
        let n: usize = t
            .parse()
            .map_err(|_| anyhow::anyhow!("expected a thread count or 'auto', got {s:?}"))?;
        Ok(Some(Parallelism::new(n)))
    }

    /// `$AUTOQ_THREADS`, if set and non-empty (`auto`/`0` count as unset).
    pub fn from_env() -> anyhow::Result<Option<Parallelism>> {
        match std::env::var("AUTOQ_THREADS") {
            Ok(s) if !s.trim().is_empty() => Self::parse_opt(&s),
            _ => Ok(None),
        }
    }

    /// Resolve a thread count: explicit choice beats `$AUTOQ_THREADS`
    /// beats auto (all cores).
    pub fn resolve(explicit: Option<Parallelism>) -> anyhow::Result<Parallelism> {
        if let Some(p) = explicit {
            return Ok(p);
        }
        if let Some(p) = Self::from_env()? {
            return Ok(p);
        }
        Ok(Self::auto())
    }

    /// Even split of `total` across `parts` consumers, never below one —
    /// the no-oversubscription budget rule shared by `Sweep` (outer
    /// per-cell workers × inner eval threads) and the shard backend
    /// (worker processes × inner threads).  The explicit `.max(1)` floors
    /// matter: `parts > total` must resolve to one thread each (mild,
    /// bounded oversubscription), not to `0` — which [`Parallelism`]'s
    /// parsers read as "auto = all cores", i.e. every consumer grabbing
    /// the whole machine, the exact blow-up the split exists to prevent.
    pub fn share_of(total: usize, parts: usize) -> Parallelism {
        Parallelism::new((total / parts.max(1)).max(1))
    }
}

/// Per-worker scratch handout: a checkout/give-back store of reusable
/// scratch states (planned-execution `Workspace`s, per-worker
/// `Coordinator`s, …).  A fan-out checks one item out per worker, reuses
/// it across every index that worker processes, and returns it at the
/// end — so steady state creates nothing new and the store never grows
/// past the peak concurrent worker count.
///
/// Scratch contents must never influence results (planned executors fully
/// overwrite every buffer they read), so the nondeterministic
/// checkout order cannot break the pool's byte-identity contract.
#[derive(Debug, Default)]
pub struct ScratchArena<W> {
    store: Mutex<Vec<W>>,
    created: AtomicUsize,
}

impl<W> ScratchArena<W> {
    pub fn new() -> ScratchArena<W> {
        ScratchArena { store: Mutex::new(Vec::new()), created: AtomicUsize::new(0) }
    }

    /// Pop an idle item, or build a fresh one with `mk` (counted).
    pub fn checkout(&self, mk: impl FnOnce() -> W) -> W {
        if let Some(w) = self.store.lock().expect("scratch arena poisoned").pop() {
            return w;
        }
        self.created.fetch_add(1, Ordering::Relaxed);
        mk()
    }

    /// Return an item for the next checkout to reuse.
    pub fn give_back(&self, w: W) {
        self.store.lock().expect("scratch arena poisoned").push(w);
    }

    /// How many items were ever built — flat across steady-state batches
    /// (the workspace-reuse regression guard).
    pub fn created(&self) -> usize {
        self.created.load(Ordering::Relaxed)
    }

    /// Inspect the idle store (all items are idle once a fan-out returns).
    pub fn peek<R>(&self, f: impl FnOnce(&[W]) -> R) -> R {
        f(&self.store.lock().expect("scratch arena poisoned"))
    }
}

/// Fan-out pool with a fixed thread budget and deterministic reduction
/// order (see module docs).
#[derive(Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool { threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The one fan-out implementation behind every `run_indexed*` entry
    /// point: indices stream off a shared atomic cursor, results come
    /// back in index order, and each worker wraps its run in
    /// `init`/`done` for per-worker state (built and finished on the
    /// worker's own thread, so `W` needs no `Send`).  Panics in `f`
    /// propagate.
    fn fan_out<W, R, I, D, F>(&self, n: usize, init: I, done: D, f: F) -> Vec<R>
    where
        R: Send,
        I: Fn() -> W + Sync,
        D: Fn(W) + Sync,
        F: Fn(&mut W, usize) -> R + Sync,
    {
        if n == 0 {
            return Vec::new(); // don't build worker state for no work
        }
        let workers = self.threads.min(n);
        if workers <= 1 {
            let mut w = init();
            let out: Vec<R> = (0..n).map(|i| f(&mut w, i)).collect();
            done(w);
            return out;
        }
        let cursor = AtomicUsize::new(0);
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let cursor = &cursor;
                    let f = &f;
                    let init = &init;
                    let done = &done;
                    s.spawn(move || {
                        let mut w = init();
                        let mut got: Vec<(usize, R)> = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            got.push((i, f(&mut w, i)));
                        }
                        done(w);
                        got
                    })
                })
                .collect();
            for h in handles {
                for (i, r) in h.join().expect("worker pool job panicked") {
                    slots[i] = Some(r);
                }
            }
        });
        slots.into_iter().map(|r| r.expect("cursor covered every index")).collect()
    }

    /// Map `0..n` through `f`, results in index order.  Runs serially
    /// when the budget (or `n`) is 1 — that path is the exact loop a
    /// pool-free caller would write, so thread count never changes
    /// results for pure-per-index jobs.  Panics in `f` propagate.
    pub fn run_indexed<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        self.fan_out(n, || (), drop, |_w, i| f(i))
    }

    /// [`run_indexed`](WorkerPool::run_indexed) with per-worker state
    /// built **inside** each worker thread by `init` and dropped when the
    /// fan-out drains — for states that are not `Send` (e.g. a worker's
    /// own `Coordinator`, the `Sweep` scheme).  Results come back in index
    /// order under the same determinism contract: state must never leak
    /// into results.
    pub fn run_indexed_with<W, R, I, F>(&self, n: usize, init: I, f: F) -> Vec<R>
    where
        R: Send,
        I: Fn() -> W + Sync,
        F: Fn(&mut W, usize) -> R + Sync,
    {
        self.fan_out(n, init, drop, f)
    }

    /// [`run_indexed`](WorkerPool::run_indexed) with a per-worker scratch
    /// state from `arena`: every worker checks one `W` out (building it
    /// with `mk` only when the arena is empty), reuses it for every index
    /// it processes, and gives it back when the fan-out drains — so
    /// scratch persists **across** fan-outs, bounded by the peak worker
    /// count.  Results come back in index order under the same determinism
    /// contract — scratch must never leak into results.
    pub fn run_indexed_scratch<W, R, M, F>(
        &self,
        n: usize,
        arena: &ScratchArena<W>,
        mk: M,
        f: F,
    ) -> Vec<R>
    where
        W: Send,
        R: Send,
        M: Fn() -> W + Sync,
        F: Fn(&mut W, usize) -> R + Sync,
    {
        self.fan_out(n, || arena.checkout(&mk), |w| arena.give_back(w), f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_arrive_in_index_order_at_any_width() {
        for threads in [1usize, 2, 3, 8] {
            let pool = WorkerPool::new(threads);
            let out = pool.run_indexed(17, |i| i * i);
            assert_eq!(out, (0..17).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_oversized_budgets() {
        let pool = WorkerPool::new(8);
        assert!(pool.run_indexed(0, |i| i).is_empty());
        assert_eq!(pool.run_indexed(1, |i| i + 1), vec![1]);
        assert_eq!(WorkerPool::new(0).threads(), 1, "budget clamps to 1");
    }

    #[test]
    fn fallible_jobs_compose_with_results() {
        let pool = WorkerPool::new(4);
        let out: anyhow::Result<Vec<usize>> =
            pool.run_indexed(9, |i| anyhow::Ok(i * 2)).into_iter().collect();
        assert_eq!(out.unwrap(), (0..9).map(|i| i * 2).collect::<Vec<_>>());
        let bad: anyhow::Result<Vec<usize>> = pool
            .run_indexed(9, |i| if i == 5 { anyhow::bail!("boom") } else { Ok(i) })
            .into_iter()
            .collect();
        assert!(bad.is_err());
    }

    #[test]
    fn scratch_arena_reuses_instead_of_rebuilding() {
        let arena: ScratchArena<Vec<u8>> = ScratchArena::new();
        let a = arena.checkout(|| vec![1, 2, 3]);
        arena.give_back(a);
        let b = arena.checkout(|| vec![9, 9]); // reuses, mk not consulted
        assert_eq!(b, vec![1, 2, 3]);
        assert_eq!(arena.created(), 1);
        arena.give_back(b);
        assert_eq!(arena.peek(|ws| ws.len()), 1);
    }

    #[test]
    fn per_worker_state_fanout_is_index_ordered_without_send() {
        // Rc is !Send: run_indexed_with must still work because each
        // worker builds and drops its state on its own thread.
        use std::rc::Rc;
        for threads in [1usize, 4] {
            let pool = WorkerPool::new(threads);
            let out = pool.run_indexed_with(
                9,
                || Rc::new(5usize),
                |state, i| i * **state,
            );
            assert_eq!(out, (0..9).map(|i| i * 5).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn scratch_fanout_is_index_ordered_and_bounds_creation() {
        for threads in [1usize, 3, 8] {
            let pool = WorkerPool::new(threads);
            let arena: ScratchArena<usize> = ScratchArena::new();
            for _round in 0..3 {
                let out = pool.run_indexed_scratch(13, &arena, || 0usize, |w, i| {
                    *w += 1; // per-worker call count — must not leak into results
                    i * 3
                });
                assert_eq!(out, (0..13).map(|i| i * 3).collect::<Vec<_>>());
            }
            assert!(arena.created() <= threads.min(13), "threads={threads}");
            assert!(arena.created() >= 1);
            // Everything checked back in between fan-outs.
            assert_eq!(arena.peek(|ws| ws.len()), arena.created());
        }
    }

    #[test]
    fn parallelism_parse_and_clamp() {
        assert_eq!(Parallelism::parse_opt("").unwrap(), None);
        assert_eq!(Parallelism::parse_opt("auto").unwrap(), None);
        assert_eq!(Parallelism::parse_opt("0").unwrap(), None);
        assert_eq!(Parallelism::parse_opt("4").unwrap(), Some(Parallelism::new(4)));
        assert!(Parallelism::parse_opt("four").is_err());
        assert_eq!(Parallelism::new(0).get(), 1);
        assert!(Parallelism::auto().get() >= 1);
        assert_eq!(Parallelism::resolve(Some(Parallelism::new(3))).unwrap().get(), 3);
    }

    #[test]
    fn share_of_floors_at_one_thread() {
        assert_eq!(Parallelism::share_of(8, 2).get(), 4);
        assert_eq!(Parallelism::share_of(7, 2).get(), 3, "integer share, no rounding up");
        // More consumers than threads: the regression this guards — a 0
        // share would be re-read as "auto = all cores" downstream.
        assert_eq!(Parallelism::share_of(2, 64).get(), 1);
        assert_eq!(Parallelism::share_of(0, 4).get(), 1);
        assert_eq!(Parallelism::share_of(4, 0).get(), 4, "zero consumers clamp to one");
    }
}
