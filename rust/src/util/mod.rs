//! From-scratch substrates: JSON, RNG, CLI parsing, statistics, logging,
//! property-based testing and the deterministic worker pool.  The offline
//! vendor set ships only `xla`, `anyhow` and `thiserror`, so everything
//! else the coordinator needs is implemented here (see DESIGN.md).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod signal;
pub mod stats;
