//! Tables 2, 3 and 4 of the paper, regenerated on this testbed.

use crate::baselines::{run_baseline, BaselineConfig, BaselinePolicy};
use crate::coordinator::Coordinator;
use crate::cost::logic::model_cost;
use crate::cost::Mode;
use crate::data::synth::{Split, SynthDataset};
use crate::quant::SavedConfig;
use crate::repro::common::{finetuned_accuracies, search_or_cached, Report, ReproCtx};
use crate::search::{Granularity, Protocol};

const TABLE_GRANS: [Granularity; 3] =
    [Granularity::Network(5), Granularity::Layer, Granularity::Channel];

fn table_protocols() -> [Protocol; 2] {
    [Protocol::resource_constrained(5.0), Protocol::accuracy_guaranteed()]
}

/// Tables 2 (quant) / 3 (binar): F / N / L / C rows × RC / AG protocols.
///
/// Two phases: the searches run first (cache-backed, serial, through the
/// shared coordinator — this also persists every model's pre-trained
/// params), then every cell's fine-tune fans out across `ctx.workers`
/// pool workers à la `Sweep`.  Results are identical to the old serial
/// loop at any worker count — each cell is deterministic in isolation.
pub fn table(
    c: &mut Coordinator,
    mode: Mode,
    models: &[String],
    ctx: &ReproCtx,
) -> anyhow::Result<()> {
    let tid = if mode == Mode::Quant { "table2" } else { "table3" };

    // Phase 1 — fp32 reference rows + searched configs, grid order.
    let mut fp_accs: Vec<f64> = Vec::with_capacity(models.len());
    let mut cells: Vec<(String, SavedConfig)> = Vec::new();
    for model in models {
        let runner = c.fresh_runner(model)?;
        let data = SynthDataset::new(42);
        let fp = runner.eval_fp32(c.runtime(), &data, Split::Val, ctx.eval_batches)?;
        fp_accs.push(fp.accuracy);
        for gran in TABLE_GRANS {
            for protocol in table_protocols() {
                let saved = search_or_cached(c, model, mode, protocol, gran, ctx)?;
                cells.push((model.clone(), saved));
            }
        }
    }

    // Phase 2 — per-cell fine-tunes across the worker pool.
    let dir = c.dir().to_path_buf();
    let accs = finetuned_accuracies(&dir, &cells, ctx)?;

    // Phase 3 — emit the report rows in grid order.
    let mut rep = Report::new(tid);
    rep.line(format!(
        "Table {} — Network {} by AutoQ (this testbed; synthetic 10-class data)",
        if mode == Mode::Quant { 2 } else { 3 },
        if mode == Mode::Quant { "Quantization" } else { "Binarization" }
    ));
    rep.line("X-F full precision; X-N uniform 5-bit; X-L per-layer; X-C per-channel");
    rep.line(format!(
        "{:<10} | {:>8} {:>6} {:>6} | {:>8} {:>6} {:>6}",
        "model", "RC err%", "actQ", "weiQ", "AG err%", "actQ", "weiQ"
    ));
    rep.line("-".repeat(62));
    let mut ci = 0usize;
    for (model, &fp_acc) in models.iter().zip(&fp_accs) {
        rep.line(format!(
            "{:<10} | {:>8.2} {:>6} {:>6} | {:>8.2} {:>6} {:>6}",
            format!("{model}-F"),
            (1.0 - fp_acc) * 100.0,
            "-",
            "-",
            (1.0 - fp_acc) * 100.0,
            "-",
            "-"
        ));
        for gran in TABLE_GRANS {
            let mut row = vec![format!("{model}-{}", gran.tag())];
            for _protocol in table_protocols() {
                let (_, saved) = &cells[ci];
                let acc = accs[ci];
                ci += 1;
                let meta = c.manifest().model(model)?.clone();
                let avg = |bits: &[u8]| {
                    bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64
                };
                let _ = model_cost(&meta.layers, &saved.wbits, &saved.abits);
                row.push(format!(
                    "{:>8.2} {:>6.2} {:>6.2}",
                    (1.0 - acc) * 100.0,
                    avg(&saved.abits),
                    avg(&saved.wbits)
                ));
            }
            rep.line(format!("{:<10} | {} | {}", row[0], row[1], row[2]));
        }
    }
    let p = rep.finish()?;
    crate::info!("wrote {}", p.display());
    Ok(())
}

/// Table 4: AutoQ vs ReLeQ / AMC / HAQ (ΔAcc and normalized logic ops).
/// Same two-phase shape as [`table`]: searches first, then all six
/// fine-tunes (baseline + AutoQ per row) across the worker pool.
pub fn table4(c: &mut Coordinator, ctx: &ReproCtx) -> anyhow::Result<()> {
    // Pairings mirror the paper (Res50→res18 substitute — DESIGN.md).
    let pairings: Vec<(&str, BaselinePolicy)> = vec![
        ("cif10", BaselinePolicy::Releq),
        ("res18", BaselinePolicy::Amc),
        ("monet", BaselinePolicy::Haq),
    ];

    // Phase 1 — fp32 reference + baseline & AutoQ searches per pairing.
    let mut fp_accs: Vec<f64> = Vec::new();
    let mut norm_logic: Vec<(f64, f64)> = Vec::new(); // (baseline, autoq)
    let mut cells: Vec<(String, SavedConfig)> = Vec::new();
    for (model, policy) in &pairings {
        let runner = c.fresh_runner(model)?;
        let data = SynthDataset::new(42);
        let fp = runner.eval_fp32(c.runtime(), &data, Split::Val, ctx.eval_batches)?;
        fp_accs.push(fp.accuracy);
        // Baseline search (AG / FLOP protocol per the original papers).
        let protocol = match policy {
            BaselinePolicy::Amc => Protocol::flop_reward(),
            _ => Protocol::accuracy_guaranteed(),
        };
        let mut bcfg = BaselineConfig::quick(*policy, Mode::Quant, protocol);
        bcfg.episodes = ctx.episodes;
        bcfg.warmup = ctx.warmup;
        bcfg.eval_batches = ctx.eval_batches;
        bcfg.seed = ctx.seed;
        let bres = run_baseline(c.runtime(), &runner, &data, &bcfg)?;
        let bsaved = SavedConfig {
            model: (*model).into(),
            mode: Mode::Quant,
            wbits: bres.best.wbits.clone(),
            abits: bres.best.abits.clone(),
            accuracy: bres.best.accuracy,
            score: bres.best.score,
        };
        // AutoQ channel-level AG on the same cell.
        let saved = search_or_cached(
            c,
            model,
            Mode::Quant,
            Protocol::accuracy_guaranteed(),
            Granularity::Channel,
            ctx,
        )?;
        let meta = c.manifest().model(model)?.clone();
        let cost = model_cost(&meta.layers, &saved.wbits, &saved.abits);
        norm_logic.push((bres.best.cost.norm_logic(), cost.norm_logic()));
        cells.push(((*model).to_string(), bsaved));
        cells.push(((*model).to_string(), saved));
    }

    // Phase 2 — all fine-tunes (2 per pairing) across the worker pool.
    let dir = c.dir().to_path_buf();
    let accs = finetuned_accuracies(&dir, &cells, ctx)?;

    // Phase 3 — rows.
    let mut rep = Report::new("table4");
    rep.line("Table 4 — Comparison against ReLeQ, AMC and HAQ (this testbed)");
    rep.line("ΔAcc = searched-and-finetuned accuracy − full-precision accuracy");
    rep.line(format!(
        "{:<10} {:<10} {:<10} {:>8} {:>12}",
        "dataset", "model", "scheme", "ΔAcc%", "norm.logic%"
    ));
    rep.line("-".repeat(56));
    for (i, (model, policy)) in pairings.iter().enumerate() {
        let fp_acc = fp_accs[i];
        let (b_logic, a_logic) = norm_logic[i];
        rep.line(format!(
            "{:<10} {:<10} {:<10} {:>8.2} {:>12.2}",
            "synth10",
            model,
            policy.name(),
            (accs[2 * i] - fp_acc) * 100.0,
            b_logic * 100.0
        ));
        rep.line(format!(
            "{:<10} {:<10} {:<10} {:>8.2} {:>12.2}",
            "synth10",
            model,
            "AutoQ",
            (accs[2 * i + 1] - fp_acc) * 100.0,
            a_logic * 100.0
        ));
    }
    let p = rep.finish()?;
    crate::info!("wrote {}", p.display());
    Ok(())
}

/// §3.4 storage-overhead audit on searched configs.
pub fn storage(c: &mut Coordinator, ctx: &ReproCtx) -> anyhow::Result<()> {
    let mut rep = Report::new("storage");
    rep.line("§3.4 — 6-bit channel bit-width records vs quantized weight payload");
    rep.line(format!(
        "{:<10} {:>14} {:>14} {:>10}",
        "model", "weights(KB)", "configs(KB)", "overhead%"
    ));
    for model in ["cif10", "res18", "sqnet", "monet"] {
        let saved = search_or_cached(
            c,
            model,
            Mode::Quant,
            Protocol::resource_constrained(5.0),
            Granularity::Channel,
            ctx,
        )?;
        let meta = c.manifest().model(model)?.clone();
        let audit = crate::quant::audit(&meta.layers, &saved.wbits, &saved.abits);
        rep.line(format!(
            "{:<10} {:>14.2} {:>14.3} {:>10.3}",
            model,
            audit.weight_bytes as f64 / 1024.0,
            audit.config_bytes as f64 / 1024.0,
            audit.overhead * 100.0
        ));
    }
    let p = rep.finish()?;
    crate::info!("wrote {}", p.display());
    Ok(())
}
