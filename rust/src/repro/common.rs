//! Shared plumbing for the repro harness: searched-config caching (so
//! `repro fig9` can reuse the searches `repro table2` ran), report sinks,
//! and the coordinator-backed search-or-load entry point.

use std::path::{Path, PathBuf};

use crate::coordinator::{Coordinator, JobOutcome, JobSpec};
use crate::cost::Mode;
use crate::data::synth::SynthDataset;
use crate::journal::{fingerprint, DurableLog};
use crate::models::ModelRunner;
use crate::quant::{load_config, save_config, SavedConfig};
use crate::runtime::{BackendKind, Parallelism};
use crate::search::{run_search, Granularity, Protocol, SearchConfig, SearchResult};
use crate::util::json::Json;
use crate::util::pool::WorkerPool;

pub fn reports_dir() -> PathBuf {
    let d = PathBuf::from("reports");
    std::fs::create_dir_all(d.join("configs")).ok();
    d
}

/// Report sink: tees formatted text to stdout and reports/<id>.txt.
pub struct Report {
    pub id: String,
    buf: String,
}

impl Report {
    pub fn new(id: &str) -> Report {
        Report { id: id.to_string(), buf: String::new() }
    }
    pub fn line(&mut self, s: impl AsRef<str>) {
        println!("{}", s.as_ref());
        self.buf.push_str(s.as_ref());
        self.buf.push('\n');
    }
    pub fn finish(self) -> anyhow::Result<PathBuf> {
        let path = reports_dir().join(format!("{}.txt", self.id));
        std::fs::write(&path, self.buf)?;
        Ok(path)
    }
}

/// Shared repro knobs (scaled-down defaults; `--paper-scale` restores §4).
#[derive(Debug, Clone)]
pub struct ReproCtx {
    pub episodes: usize,
    pub warmup: usize,
    pub eval_batches: usize,
    pub finetune_steps: usize,
    pub seed: u64,
    pub fresh: bool,
    pub paper_scale: bool,
    /// Outer workers for the per-cell fine-tune fan-out (`--workers`).
    pub workers: usize,
    /// Backend each fine-tune worker opens (`--backend`).
    pub backend: Option<BackendKind>,
    /// Inner eval threads per worker (`--threads`; `None` = split the
    /// machine budget evenly across workers, the `Sweep` rule).
    pub threads: Option<Parallelism>,
    /// Worker processes per fine-tune worker when `backend` is the shard
    /// backend (`--shard-workers`); ignored otherwise.
    pub shard_workers: Option<usize>,
    /// Remote `autoq worker --listen` hosts for the shard backend
    /// (`--shard-hosts`; `None` = `$AUTOQ_SHARD_HOSTS`).  Dealt out as
    /// disjoint buckets across the fine-tune workers, the `Sweep` rule.
    pub shard_hosts: Option<Vec<String>>,
    /// Shard wire encoding (`--shard-encoding`; `None` =
    /// `$AUTOQ_SHARD_ENCODING`, else binary).
    pub shard_encoding: Option<crate::runtime::shard::Encoding>,
    /// `autoq serve` address (`--daemon`); when set, searches run through
    /// the daemon (sharing its eval cache) instead of in-process.
    /// Fine-tunes and report assembly stay local either way.
    pub daemon: Option<String>,
}

impl Default for ReproCtx {
    fn default() -> Self {
        ReproCtx {
            episodes: 30,
            warmup: 8,
            eval_batches: 2,
            finetune_steps: 80,
            seed: 1,
            fresh: false,
            paper_scale: false,
            workers: 2,
            backend: None,
            threads: None,
            shard_workers: None,
            shard_hosts: None,
            shard_encoding: None,
            daemon: None,
        }
    }
}

fn cache_key(model: &str, mode: Mode, protocol: &Protocol, gran: Granularity) -> PathBuf {
    reports_dir().join(format!(
        "configs/{model}_{}_{}_{}.json",
        mode.as_str(),
        protocol.name(),
        gran.tag()
    ))
}

/// The repro cells' journal, next to the config files it indexes.  Unlike
/// the bare `key.exists()` check, journal entries carry the search spec's
/// fingerprint, so a cell whose knobs changed (episodes, seed, …) re-runs
/// instead of silently reusing a config searched under different settings.
fn repro_journal() -> Option<DurableLog> {
    let path = reports_dir().join("configs").join("repro.journal");
    match DurableLog::open(&path) {
        Ok(log) => Some(log),
        Err(e) => {
            crate::warn_!("repro journal unavailable ({e:#}); cells will not checkpoint");
            None
        }
    }
}

/// Search one (model, mode, protocol, granularity) cell through the
/// coordinator job API, or return the cached best config from a previous
/// repro run — either a journaled cell whose spec fingerprint still
/// matches, or a legacy pre-journal config file.
pub fn search_or_cached(
    c: &mut Coordinator,
    model: &str,
    mode: Mode,
    protocol: Protocol,
    gran: Granularity,
    ctx: &ReproCtx,
) -> anyhow::Result<SavedConfig> {
    let key = cache_key(model, mode, &protocol, gran);
    let spec = JobSpec::search(model)
        .mode(mode)
        .protocol(protocol)
        .granularity(gran)
        .episodes(ctx.episodes)
        .warmup(ctx.warmup)
        .eval_batches(ctx.eval_batches)
        .seed(ctx.seed)
        .paper_scale(ctx.paper_scale)
        .build()?;
    let id = key.file_name().and_then(|s| s.to_str()).unwrap_or("cell").to_string();
    let fp = fingerprint(spec.to_json().to_string().as_bytes());
    let mut log = repro_journal();
    if !ctx.fresh {
        if let Some(payload) = log.as_ref().and_then(|l| l.recorded(&id, fp)) {
            // Journaled under the same spec: re-materialize the config file
            // if it was deleted or diverged, then load it.
            if std::fs::read(&key).ok().as_deref() != Some(payload) {
                std::fs::write(&key, payload)?;
            }
            crate::debug!("repro journal hit: {}", key.display());
            return load_config(&key);
        }
        if key.exists() {
            // Legacy pre-journal cache entry: reuse as before (no
            // fingerprint to check against).
            crate::debug!("cache hit: {}", key.display());
            return load_config(&key);
        }
    }
    if let Some(addr) = &ctx.daemon {
        let report = crate::serve::run_job_via_daemon(addr, &spec)?;
        save_config_from_report(&key, model, mode, &report)?;
    } else {
        let report = c.run(&spec)?;
        let JobOutcome::Search { best, .. } = &report.outcome else {
            anyhow::bail!("search job returned a non-search report");
        };
        save_config(&key, model, mode, best)?;
    }
    if let Some(log) = log.as_mut() {
        match std::fs::read(&key) {
            Ok(payload) => {
                if let Err(e) = log.record_done(&id, fp, &payload) {
                    crate::warn_!("repro journal append failed: {e:#}");
                }
            }
            Err(e) => crate::warn_!("cannot journal repro cell {id}: {e:#}"),
        }
    }
    load_config(&key)
}

/// Derive the `load_config`-compatible cache entry from a daemon search
/// report: its `search` object (`JobOutcome::Search` as serialized by
/// `JobReport::to_json`) is a superset of the fields `load_config` reads,
/// so the cache entry carries the same bits/accuracy/score a local
/// `save_config` would have written.
fn save_config_from_report(
    key: &Path,
    model: &str,
    mode: Mode,
    report: &Json,
) -> anyhow::Result<()> {
    let s = report
        .req("search")
        .map_err(|e| anyhow::anyhow!("daemon report has no search outcome: {e}"))?;
    let j = Json::obj(vec![
        ("model", model.into()),
        ("mode", mode.as_str().into()),
        ("accuracy", s.req("accuracy")?.clone()),
        ("score", s.req("score")?.clone()),
        ("wbits", s.req("wbits")?.clone()),
        ("abits", s.req("abits")?.clone()),
    ]);
    std::fs::write(key, j.to_string())?;
    Ok(())
}

/// Run one cell on an externally-owned runner (fig8 shares a runner between
/// the hierarchical and flat-DDPG searches).
pub fn run_cell(
    c: &mut Coordinator,
    runner: &ModelRunner,
    data: &SynthDataset,
    mode: Mode,
    protocol: Protocol,
    gran: Granularity,
    ctx: &ReproCtx,
) -> anyhow::Result<SearchResult> {
    let mut cfg = SearchConfig::quick(mode, protocol, gran);
    cfg.episodes = ctx.episodes;
    cfg.warmup = ctx.warmup;
    cfg.eval_batches = ctx.eval_batches;
    cfg.seed = ctx.seed;
    if ctx.paper_scale {
        cfg = cfg.paper_scale();
    }
    run_search(c.runtime(), runner, data, &cfg)
}

/// Fine-tune a searched config and report the recovered accuracy (the
/// tables report fine-tuned numbers).
pub fn finetuned_accuracy(
    c: &mut Coordinator,
    model: &str,
    saved: &SavedConfig,
    ctx: &ReproCtx,
) -> anyhow::Result<f64> {
    if ctx.finetune_steps == 0 {
        return Ok(saved.accuracy);
    }
    let mut runner = c.fresh_runner(model)?; // fresh copy of pre-trained params
    let data = SynthDataset::new(42);
    let tc = crate::finetune::TrainConfig::finetune(
        saved.mode,
        saved.wbits.clone(),
        saved.abits.clone(),
        ctx.finetune_steps,
    );
    let rep = crate::finetune::train(c.runtime(), &mut runner, &data, &tc)?;
    // Fine-tuning can only help; guard against a regression run.
    Ok(rep.final_eval.accuracy.max(saved.accuracy))
}

/// Fine-tune many searched cells in parallel — the `Sweep` worker scheme
/// routed through `util::pool`: outer per-cell workers each own a
/// `Coordinator` (built inside the worker thread and reused across every
/// cell that worker processes), inner eval threads get an even share of
/// the machine budget unless `ctx.threads` pins one, so the fan-out never
/// oversubscribes cores.  Each cell's fine-tune is deterministic given the
/// persisted pre-trained params (callers run the searches first, which
/// persists them), so results in cell order are identical to a serial
/// `finetuned_accuracy` loop at any worker count.
pub fn finetuned_accuracies(
    dir: &Path,
    cells: &[(String, SavedConfig)],
    ctx: &ReproCtx,
) -> anyhow::Result<Vec<f64>> {
    if cells.is_empty() {
        return Ok(Vec::new());
    }
    if ctx.finetune_steps == 0 {
        return Ok(cells.iter().map(|(_, saved)| saved.accuracy).collect());
    }
    let workers = ctx.workers.max(1).min(cells.len());
    // The Sweep rule: an even share of the machine budget per worker,
    // never below one thread (`workers > cores` must not oversubscribe).
    let inner = match ctx.threads {
        Some(p) => p,
        None => Parallelism::share_of(Parallelism::resolve(None)?.get(), workers),
    };
    crate::info!(
        "repro: fine-tuning {} cell(s) on {workers} worker(s) × {} eval thread(s)",
        cells.len(),
        inner.get()
    );
    let pool = WorkerPool::new(workers);
    let backend = ctx.backend;
    // Disjoint remote-host buckets per worker (a listening worker serves
    // one session at a time).  The pool's init closure carries no worker
    // index, so buckets are dealt first-come — disjointness is what
    // matters, not which worker gets which bucket.
    let hosts = crate::runtime::shard::resolve_hosts(ctx.shard_hosts.clone())?;
    let host_parts = crate::runtime::shard::partition_hosts(&hosts, workers);
    let next_bucket = std::sync::atomic::AtomicUsize::new(0);
    let results: Vec<anyhow::Result<f64>> = pool.run_indexed_with(
        cells.len(),
        || {
            let b = next_bucket.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % workers;
            let opts = crate::runtime::RuntimeOpts {
                threads: Some(inner),
                shard_workers: ctx.shard_workers,
                shard_hosts: Some(host_parts[b].clone()),
                shard_encoding: ctx.shard_encoding,
            };
            Coordinator::open_full(dir, backend, opts)
        },
        |coord, i| match coord {
            Ok(c) => finetuned_accuracy(c, &cells[i].0, &cells[i].1, ctx),
            Err(e) => Err(anyhow::anyhow!("fine-tune worker failed to open runtime: {e:#}")),
        },
    );
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn daemon_search_report_roundtrips_through_config_cache() {
        let report = Json::parse(concat!(
            r#"{"id":"x","secs":1.5,"spec":{"kind":"search"},"search":{"#,
            r#""accuracy":0.875,"loss":0.4,"reward":0.7,"score":12.5,"#,
            r#""norm_logic":0.1,"avg_wbits":3.0,"avg_abits":3.0,"#,
            r#""wbits":[4,5,0],"abits":[3,3],"history":[]}}"#
        ))
        .unwrap();
        let dir = std::env::temp_dir().join(format!("autoq_daemon_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let key = dir.join("cif10_quant_hier_kernel.json");
        save_config_from_report(&key, "cif10", Mode::Quant, &report).unwrap();
        let cfg = load_config(&key).unwrap();
        assert_eq!(cfg.model, "cif10");
        assert_eq!(cfg.mode, Mode::Quant);
        assert_eq!(cfg.wbits, vec![4, 5, 0]);
        assert_eq!(cfg.abits, vec![3, 3]);
        assert!((cfg.accuracy - 0.875).abs() < 1e-12);
        assert!((cfg.score - 12.5).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();

        // A non-search report (e.g. an eval job handed to --daemon by
        // mistake) is rejected instead of writing a corrupt cache entry.
        let bad = Json::parse(r#"{"id":"x","secs":1.0,"spec":{},"eval":{}}"#).unwrap();
        assert!(save_config_from_report(&key, "cif10", Mode::Quant, &bad).is_err());
    }
}
