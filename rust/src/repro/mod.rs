//! `autoq repro <id>` — regenerate the paper's tables and figures (see
//! DESIGN.md experiment index).  Results are teed to `reports/<id>.txt`;
//! searched configurations are cached under `reports/configs/` so figures
//! can reuse the searches the tables ran.

pub mod common;
pub mod figs;
pub mod tables;

use crate::cost::Mode;
use crate::util::cli::Args;
use common::ReproCtx;

pub fn cmd_repro(rest: &[String]) -> anyhow::Result<()> {
    let a = Args::new("repro")
        .opt("episodes", "30", "search episodes per cell")
        .opt("warmup", "8", "constant-noise episodes")
        .opt("eval-batches", "2", "val batches per evaluation")
        .opt("finetune-steps", "80", "fine-tune steps for table rows (0 = skip)")
        .opt("models", "cif10", "comma-separated models for table2/3")
        .opt("runs", "3", "independent runs for fig8")
        .opt("seed", "1", "base seed")
        .opt("workers", "2", "parallel per-cell fine-tune workers for table rows")
        .opt("backend", "", "pjrt|reference|shard (default: $AUTOQ_BACKEND, else auto)")
        .opt("threads", "", "eval threads per worker (default: split cores across workers)")
        .opt(
            "shard-workers",
            "",
            "worker processes for --backend shard (default: $AUTOQ_SHARD_WORKERS, else 2)",
        )
        .opt(
            "shard-hosts",
            "",
            "remote worker host:port list for --backend shard (default: $AUTOQ_SHARD_HOSTS)",
        )
        .opt(
            "shard-encoding",
            "",
            "shard wire encoding json|binary (default: $AUTOQ_SHARD_ENCODING, else binary)",
        )
        .opt(
            "daemon",
            "",
            "autoq serve address — run searches through the daemon's job queue + eval cache",
        )
        .flag("fresh", "ignore cached searched configs")
        .flag("paper-scale", "paper's 400-episode schedule")
        .parse(rest)?;
    let backend = crate::runtime::BackendKind::parse_opt(&a.get("backend"))?;
    let threads = crate::runtime::Parallelism::parse_opt(&a.get("threads"))?;
    let shard_workers = crate::runtime::shard::parse_workers_opt(&a.get("shard-workers"))?;
    let shard_hosts = crate::runtime::shard::parse_hosts_opt(&a.get("shard-hosts"))?;
    let shard_encoding = crate::runtime::shard::Encoding::parse_opt(&a.get("shard-encoding"))?;
    let daemon = Some(a.get("daemon")).filter(|d| !d.is_empty());
    let ctx = ReproCtx {
        episodes: a.get_usize("episodes")?,
        warmup: a.get_usize("warmup")?,
        eval_batches: a.get_usize("eval-batches")?,
        finetune_steps: a.get_usize("finetune-steps")?,
        seed: a.get_u64("seed")?,
        fresh: a.get_bool("fresh"),
        paper_scale: a.get_bool("paper-scale"),
        workers: a.get_usize("workers")?,
        backend,
        threads,
        shard_workers,
        shard_hosts: shard_hosts.clone(),
        shard_encoding,
        daemon,
    };
    let models: Vec<String> = a.get("models").split(',').map(str::to_string).collect();
    let what = a.positional.first().cloned().unwrap_or_else(|| "help".into());
    let runs = a.get_usize("runs")?;
    let mut coord = crate::coordinator::Coordinator::open_full(
        &crate::coordinator::Coordinator::default_dir(),
        backend,
        crate::runtime::RuntimeOpts { threads, shard_workers, shard_hosts, shard_encoding },
    )?;
    match what.as_str() {
        "fig1" => fig1(),
        "table2" => tables::table(&mut coord, Mode::Quant, &models, &ctx),
        "table3" => tables::table(&mut coord, Mode::Binar, &models, &ctx),
        "table4" => tables::table4(&mut coord, &ctx),
        "storage" => tables::storage(&mut coord, &ctx),
        "fig4" | "fig5" | "fig7" => figs::per_layer_bits(&mut coord, &what, &ctx),
        "fig6" => figs::fig6(&mut coord, &ctx),
        "fig8" => figs::fig8(&mut coord, &ctx, runs),
        "fig9" | "fig10" | "fig11" | "fig12" => figs::fpga_figs(&mut coord, &what, &ctx),
        "all" => {
            fig1()?;
            tables::table(&mut coord, Mode::Quant, &models, &ctx)?;
            tables::table(&mut coord, Mode::Binar, &models, &ctx)?;
            tables::table4(&mut coord, &ctx)?;
            tables::storage(&mut coord, &ctx)?;
            for f in ["fig4", "fig5", "fig7"] {
                figs::per_layer_bits(&mut coord, f, &ctx)?;
            }
            figs::fig6(&mut coord, &ctx)?;
            figs::fig8(&mut coord, &ctx, runs)?;
            for f in ["fig9", "fig10", "fig11", "fig12"] {
                figs::fpga_figs(&mut coord, f, &ctx)?;
            }
            Ok(())
        }
        other => anyhow::bail!(
            "repro target {other:?} unknown — try fig1|table2|table3|table4|storage|fig4..fig12|all"
        ),
    }
}

/// Fig. 1: normalized hardware cost vs bit-width, quant vs binar.
fn fig1() -> anyhow::Result<()> {
    let mut rep = common::Report::new("fig1");
    rep.line("FIG1 — normalized (to fp32 MAC) transistor cost of the datapath");
    rep.line(format!("{:>4} {:>12} {:>12}", "bits", "quant", "binar"));
    for (b, q, x) in crate::cost::hardware::fig1_table(16) {
        rep.line(format!("{b:>4} {q:>12.5} {x:>12.5}"));
    }
    let p = rep.finish()?;
    crate::info!("wrote {}", p.display());
    Ok(())
}
