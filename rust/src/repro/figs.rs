//! Figures 4–12 of the paper, regenerated on this testbed as text series
//! (one row per x-axis point, ready for plotting).

use crate::baselines::{run_baseline, BaselineConfig, BaselinePolicy};
use crate::coordinator::Coordinator;
use crate::cost::Mode;
use crate::data::synth::SynthDataset;
use crate::quant::SavedConfig;
use crate::repro::common::{run_cell, search_or_cached, Report, ReproCtx};
use crate::search::{Granularity, Protocol};
use crate::sim::{Arch, FpgaSim};
use crate::util::stats;

/// Figs 4 / 5 / 7: per-layer average weight & activation QBNs of res18
/// under RC (fig4), AG (fig5) or the FLOP reward (fig7).
pub fn per_layer_bits(c: &mut Coordinator, fig: &str, ctx: &ReproCtx) -> anyhow::Result<()> {
    let (protocol, title) = match fig {
        "fig4" => (Protocol::resource_constrained(5.0), "resource-constrained"),
        "fig5" => (Protocol::accuracy_guaranteed(), "accuracy-guaranteed"),
        "fig7" => (Protocol::flop_reward(), "FLOP-based reward"),
        _ => anyhow::bail!("unknown per-layer fig {fig}"),
    };
    let model = "res18";
    let saved = search_or_cached(c, model, Mode::Quant, protocol, Granularity::Channel, ctx)?;
    let meta = c.manifest().model(model)?.clone();
    let mut rep = Report::new(fig);
    rep.line(format!(
        "{} — per-layer average QBNs of {model}, {} channel-level search",
        fig.to_uppercase(),
        title
    ));
    rep.line(format!("{:<6} {:<14} {:>8} {:>8}", "layer", "name", "avg_wQBN", "avg_aQBN"));
    for (t, l) in meta.layers.iter().enumerate() {
        let avg_w = saved.wbits[l.w_off..l.w_off + l.w_len]
            .iter()
            .map(|&b| b as f64)
            .sum::<f64>()
            / l.w_len as f64;
        let avg_a = saved.abits[l.a_off..l.a_off + l.a_len]
            .iter()
            .map(|&b| b as f64)
            .sum::<f64>()
            / l.a_len as f64;
        rep.line(format!("{:<6} {:<14} {:>8.2} {:>8.2}", t + 1, l.name, avg_w, avg_a));
    }
    let p = rep.finish()?;
    crate::info!("wrote {}", p.display());
    Ok(())
}

/// Fig 6: weight-QBN distributions of layers 9–16 of res18 (RC channel
/// search) — histograms over channel bit-widths.
pub fn fig6(c: &mut Coordinator, ctx: &ReproCtx) -> anyhow::Result<()> {
    let model = "res18";
    let saved = search_or_cached(
        c,
        model,
        Mode::Quant,
        Protocol::resource_constrained(5.0),
        Granularity::Channel,
        ctx,
    )?;
    let meta = c.manifest().model(model)?.clone();
    let mut rep = Report::new("fig6");
    rep.line("FIG6 — weight QBN distributions, layers 9–16 of res18 (RC channel search)");
    rep.line(format!("{:<6} {:<14} {}", "layer", "name", "count per QBN 0..8+ (col = bits)"));
    for (t, l) in meta.layers.iter().enumerate() {
        if !(8..16).contains(&t) {
            continue;
        }
        let bits: Vec<f64> = saved.wbits[l.w_off..l.w_off + l.w_len]
            .iter()
            .map(|&b| b as f64)
            .collect();
        let hist = stats::histogram(&bits, 0.0, 9.0, 9);
        let cells: Vec<String> = hist.iter().map(|c| format!("{c:>4}")).collect();
        rep.line(format!("{:<6} {:<14} {}", t + 1, l.name, cells.join("")));
    }
    let p = rep.finish()?;
    crate::info!("wrote {}", p.display());
    Ok(())
}

/// Fig 8: hierarchical AutoQ vs flat DDPG learning curves (avg of `runs`
/// seeds, resource-constrained channel search on cif10).
pub fn fig8(c: &mut Coordinator, ctx: &ReproCtx, runs: usize) -> anyhow::Result<()> {
    let model = "cif10";
    let runner = c.fresh_runner(model)?;
    let data = SynthDataset::new(42);
    let episodes = ctx.episodes;
    let mut hiro_acc = vec![0.0f64; episodes];
    let mut flat_acc = vec![0.0f64; episodes];
    for run in 0..runs {
        let mut rc = ctx.clone();
        rc.seed = ctx.seed + run as u64 * 101;
        let res = run_cell(
            c,
            &runner,
            &data,
            Mode::Quant,
            Protocol::resource_constrained(5.0),
            Granularity::Channel,
            &rc,
        )?;
        for (i, st) in res.history.iter().enumerate() {
            hiro_acc[i] += st.accuracy / runs as f64;
        }
        let mut bcfg = BaselineConfig::quick(
            BaselinePolicy::FlatDdpg,
            Mode::Quant,
            Protocol::resource_constrained(5.0),
        );
        bcfg.episodes = episodes;
        bcfg.warmup = rc.warmup;
        bcfg.eval_batches = rc.eval_batches;
        bcfg.seed = rc.seed;
        let bres = run_baseline(c.runtime(), &runner, &data, &bcfg)?;
        for (i, st) in bres.history.iter().enumerate() {
            flat_acc[i] += st.accuracy / runs as f64;
        }
    }
    let mut rep = Report::new("fig8");
    rep.line(format!(
        "FIG8 — mean inference accuracy over {runs} runs, RC channel search on cif10"
    ));
    rep.line(format!("{:<8} {:>12} {:>12}", "episode", "hiro(AutoQ)", "flat DDPG"));
    let h_s = stats::ema(&hiro_acc, 0.3);
    let f_s = stats::ema(&flat_acc, 0.3);
    for ep in 0..episodes {
        rep.line(format!("{:<8} {:>12.4} {:>12.4}", ep, h_s[ep], f_s[ep]));
    }
    let h_final = stats::mean(&h_s[episodes.saturating_sub(5)..]);
    let f_final = stats::mean(&f_s[episodes.saturating_sub(5)..]);
    rep.line(format!(
        "final-5-episode mean: hiro {h_final:.4} vs flat {f_final:.4} (paper: >80% vs ~40%)"
    ));
    let p = rep.finish()?;
    crate::info!("wrote {}", p.display());
    Ok(())
}

/// Figs 9–12: FPS / energy of quantized & binarized res18 + monet on the
/// spatial and temporal accelerators (RC for 9/10, AG + FR for 11/12).
pub fn fpga_figs(c: &mut Coordinator, fig: &str, ctx: &ReproCtx) -> anyhow::Result<()> {
    let (protocols, metric): (Vec<(&str, Protocol)>, &str) = match fig {
        "fig9" => (vec![("RC", Protocol::resource_constrained(5.0))], "fps"),
        "fig10" => (vec![("RC", Protocol::resource_constrained(5.0))], "energy"),
        "fig11" => (
            vec![
                ("AG", Protocol::accuracy_guaranteed()),
                ("FR", Protocol::flop_reward()),
            ],
            "fps",
        ),
        "fig12" => (
            vec![
                ("AG", Protocol::accuracy_guaranteed()),
                ("FR", Protocol::flop_reward()),
            ],
            "energy",
        ),
        _ => anyhow::bail!("unknown fpga fig {fig}"),
    };
    let mut rep = Report::new(fig);
    rep.line(format!(
        "{} — {} on the FPGA simulators (paper §4.5; res18 stands in for Res50)",
        fig.to_uppercase(),
        if metric == "fps" { "frames/s" } else { "inference energy (mJ)" }
    ));
    rep.line(format!(
        "{:<8} {:<5} {:<5} {:<6} {:>12} {:>12} {:>6}",
        "model", "mode", "prot", "gran", "temporal", "spatial", "util_s"
    ));
    for model in ["res18", "monet"] {
        let meta = c.manifest().model(model)?.clone();
        for mode in [Mode::Quant, Mode::Binar] {
            for (ptag, protocol) in &protocols {
                // F and N need no search; L and C come from the cache.
                let mut rows: Vec<(String, Vec<u8>, Vec<u8>)> = vec![
                    ("F".into(), vec![32; meta.w_channels], vec![32; meta.a_channels]),
                    ("N".into(), vec![5; meta.w_channels], vec![5; meta.a_channels]),
                ];
                for gran in [Granularity::Layer, Granularity::Channel] {
                    let saved: SavedConfig =
                        search_or_cached(c, model, mode, *protocol, gran, ctx)?;
                    rows.push((gran.tag().into(), saved.wbits, saved.abits));
                }
                for (tag, wbits, abits) in rows {
                    let t = FpgaSim::new(Arch::Temporal, mode).run(&meta.layers, &wbits, &abits);
                    let s = FpgaSim::new(Arch::Spatial, mode).run(&meta.layers, &wbits, &abits);
                    let (vt, vs) = if metric == "fps" {
                        (t.fps, s.fps)
                    } else {
                        (t.energy_j * 1e3, s.energy_j * 1e3)
                    };
                    rep.line(format!(
                        "{:<8} {:<5} {:<5} {:<6} {:>12.2} {:>12.2} {:>6.3}",
                        model,
                        mode.as_str(),
                        ptag,
                        tag,
                        vt,
                        vs,
                        s.utilization
                    ));
                }
            }
        }
    }
    let p = rep.finish()?;
    crate::info!("wrote {}", p.display());
    Ok(())
}
