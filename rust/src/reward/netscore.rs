//! NetScore-based extrinsic reward (paper Eq. 2, [Wong 35]):
//!
//!   Ω(N) = 20 · log( a(N)^α / (p(N)^β · m(N)^γ) )
//!
//! a(N) — validation accuracy (in [0,1] here; the paper's percentage form
//! only shifts Ω by a constant), p(N) — weight payload normalized to the
//! fp32 model, m(N) — bit-level logic ops normalized to the fp32 model.
//! Normalized p/m keep Ω platform-independent; constant factors cancel in
//! the argmax the agent chases.
//!
//! Search protocols (§3.3):
//!   * resource-constrained:  α=1, β=0, γ=0  (pure accuracy; the budget is
//!     enforced structurally by Algorithm 1's action-space limiting)
//!   * accuracy-guaranteed:   α=2, β=0.5, γ=0.5
//!   * flop-based (AMC [9]):  α=2, β=0,   γ=0.5 — ignores the weight count,
//!     the §4.3 ablation.

use crate::cost::logic::ModelCost;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetScore {
    pub alpha: f64,
    pub beta: f64,
    pub gamma: f64,
}

/// Floors keep Ω finite when a config prunes everything (a=0 or m=0).
const EPS: f64 = 1e-6;

impl NetScore {
    pub const RESOURCE_CONSTRAINED: NetScore = NetScore { alpha: 1.0, beta: 0.0, gamma: 0.0 };
    pub const ACCURACY_GUARANTEED: NetScore = NetScore { alpha: 2.0, beta: 0.5, gamma: 0.5 };
    pub const FLOP_BASED: NetScore = NetScore { alpha: 2.0, beta: 0.0, gamma: 0.5 };

    /// Ω(N) for accuracy `acc` in [0,1] and a model cost audit.
    pub fn score(&self, acc: f64, cost: &ModelCost) -> f64 {
        let a = acc.max(EPS);
        let p = cost.norm_params().max(EPS);
        let m = cost.norm_logic().max(EPS);
        20.0 * (a.powf(self.alpha) / (p.powf(self.beta) * m.powf(self.gamma))).log10()
    }

    /// Immediate extrinsic reward: Ω scaled to a [-1, ~3] band the critic
    /// learns comfortably (Ω/20 = the plain log10 argument).
    pub fn reward(&self, acc: f64, cost: &ModelCost) -> f64 {
        self.score(acc, cost) / 20.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(norm_logic: f64, norm_params: f64) -> ModelCost {
        // Construct a cost with the desired normalized ratios.
        let fp = 1_000_000_000u64;
        ModelCost {
            logic_ops: (norm_logic * fp as f64) as u64,
            logic_fp: fp,
            weight_bits: (norm_params * fp as f64) as u64,
            weight_bits_fp: fp,
        }
    }

    #[test]
    fn rc_protocol_ignores_cost() {
        let ns = NetScore::RESOURCE_CONSTRAINED;
        let a = ns.score(0.9, &cost(0.5, 0.5));
        let b = ns.score(0.9, &cost(0.01, 0.01));
        assert!((a - b).abs() < 1e-9, "RC must ignore cost terms");
        assert!(ns.score(0.95, &cost(0.5, 0.5)) > a);
    }

    #[test]
    fn ag_protocol_rewards_smaller_models() {
        let ns = NetScore::ACCURACY_GUARANTEED;
        let big = ns.score(0.9, &cost(0.5, 0.5));
        let small = ns.score(0.9, &cost(0.05, 0.05));
        assert!(small > big);
    }

    #[test]
    fn ag_trades_accuracy_for_cost() {
        let ns = NetScore::ACCURACY_GUARANTEED;
        // 1% accuracy drop for 10x cost reduction must win under AG.
        let keep = ns.score(0.90, &cost(0.5, 0.5));
        let shrink = ns.score(0.89, &cost(0.05, 0.05));
        assert!(shrink > keep);
    }

    #[test]
    fn flop_based_ignores_weights() {
        let ns = NetScore::FLOP_BASED;
        let a = ns.score(0.9, &cost(0.1, 0.9));
        let b = ns.score(0.9, &cost(0.1, 0.01));
        assert!((a - b).abs() < 1e-9, "FLOP reward must ignore p(N)");
    }

    #[test]
    fn degenerate_configs_finite() {
        for ns in [
            NetScore::RESOURCE_CONSTRAINED,
            NetScore::ACCURACY_GUARANTEED,
            NetScore::FLOP_BASED,
        ] {
            let s = ns.score(0.0, &cost(0.0, 0.0));
            assert!(s.is_finite());
        }
    }
}
