//! Roofline model [Williams 34] (paper §3): a lightweight stand-in for the
//! "heavy and slow hardware simulators" HAQ queries — AutoQ instead fits
//! approximately linear relationships between network parameters and
//! hardware latency/energy and plugs them into the reward.
//!
//! latency = max(ops / peak_ops_per_s, bytes / bandwidth)     (the roofline)
//! energy  = ops · e_op + bytes · e_byte
//!
//! `fit` recovers (peak, bandwidth) from observed (ops, bytes, latency)
//! triples by least squares on the two regimes, which is exactly the
//! "fitting parameters" workflow the paper describes; presets model the
//! two FPGA accelerator templates of §4.5.

/// Platform description: compute roof, memory roof, energy coefficients.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Roofline {
    /// Peak bit-level logic ops per second.
    pub peak_ops: f64,
    /// Off-chip bandwidth, bytes per second.
    pub bandwidth: f64,
    /// Energy per bit-level logic op (J).
    pub e_op: f64,
    /// Energy per byte moved (J).
    pub e_byte: f64,
}

impl Roofline {
    /// Zynq-7000-class temporal (BISMO-like bit-serial @150 MHz) template.
    pub fn fpga_temporal() -> Roofline {
        Roofline {
            peak_ops: 150e6 * 4096.0, // 150 MHz × 4096 bit-serial lanes
            bandwidth: 4.2e9,         // DDR3 on the ZC702
            e_op: 2.0e-12,
            e_byte: 80.0e-12,
        }
    }
    /// Spatial (BitFusion-like fusion-unit array @100 MHz) template.
    pub fn fpga_spatial() -> Roofline {
        Roofline {
            peak_ops: 100e6 * 6144.0,
            bandwidth: 4.2e9,
            e_op: 1.6e-12,
            e_byte: 80.0e-12,
        }
    }

    /// Roofline latency (seconds) for a workload of `ops` bit-level logic
    /// ops that moves `bytes` bytes.
    pub fn latency(&self, ops: f64, bytes: f64) -> f64 {
        (ops / self.peak_ops).max(bytes / self.bandwidth)
    }

    pub fn energy(&self, ops: f64, bytes: f64) -> f64 {
        ops * self.e_op + bytes * self.e_byte
    }

    /// Is the workload memory-bound on this platform?  Drives the β/γ
    /// choice of §3.3 (increase β when memory-bound, γ when compute-bound).
    pub fn memory_bound(&self, ops: f64, bytes: f64) -> bool {
        bytes / self.bandwidth > ops / self.peak_ops
    }

    /// Fit (peak_ops, bandwidth) from (ops, bytes, latency) samples: each
    /// sample is assigned to its binding regime iteratively (2 rounds of
    /// Lloyd-style reassignment), then each roof is the least-squares slope
    /// through the origin.
    pub fn fit(samples: &[(f64, f64, f64)]) -> Option<Roofline> {
        if samples.len() < 2 {
            return None;
        }
        let mut peak: f64 = 1e12;
        let mut bw: f64 = 1e10;
        for _ in 0..4 {
            let (mut num_c, mut den_c, mut num_m, mut den_m) = (0.0, 0.0, 0.0, 0.0);
            for &(ops, bytes, lat) in samples {
                if ops / peak >= bytes / bw {
                    // Compute-bound: lat ≈ ops / peak.
                    num_c += ops * ops;
                    den_c += ops * lat;
                } else {
                    num_m += bytes * bytes;
                    den_m += bytes * lat;
                }
            }
            if den_c > 0.0 {
                peak = num_c / den_c;
            }
            if den_m > 0.0 {
                bw = num_m / den_m;
            }
        }
        Some(Roofline { peak_ops: peak, bandwidth: bw, e_op: 0.0, e_byte: 0.0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_takes_binding_roof() {
        let r = Roofline { peak_ops: 100.0, bandwidth: 10.0, e_op: 1.0, e_byte: 2.0 };
        // Compute-bound: 1000 ops → 10 s vs 10 bytes → 1 s.
        assert_eq!(r.latency(1000.0, 10.0), 10.0);
        assert!(!r.memory_bound(1000.0, 10.0));
        // Memory-bound.
        assert_eq!(r.latency(10.0, 1000.0), 100.0);
        assert!(r.memory_bound(10.0, 1000.0));
    }

    #[test]
    fn energy_is_linear() {
        let r = Roofline { peak_ops: 1.0, bandwidth: 1.0, e_op: 2.0, e_byte: 3.0 };
        assert_eq!(r.energy(10.0, 100.0), 20.0 + 300.0);
    }

    #[test]
    fn fit_recovers_both_roofs() {
        let truth = Roofline { peak_ops: 1e9, bandwidth: 1e7, e_op: 0.0, e_byte: 0.0 };
        let mut samples = Vec::new();
        for i in 1..20 {
            // Compute-heavy samples.
            let ops = i as f64 * 1e8;
            samples.push((ops, 10.0, truth.latency(ops, 10.0)));
            // Memory-heavy samples.
            let bytes = i as f64 * 1e6;
            samples.push((10.0, bytes, truth.latency(10.0, bytes)));
        }
        let fit = Roofline::fit(&samples).unwrap();
        assert!((fit.peak_ops / truth.peak_ops - 1.0).abs() < 0.05, "peak {}", fit.peak_ops);
        assert!((fit.bandwidth / truth.bandwidth - 1.0).abs() < 0.05, "bw {}", fit.bandwidth);
    }

    #[test]
    fn presets_sane() {
        let t = Roofline::fpga_temporal();
        let s = Roofline::fpga_spatial();
        assert!(t.peak_ops > 1e10 && s.peak_ops > 1e10);
        // Conv workload: compute-bound on both; FC workload: memory-bound
        // (the §4.5 observation about fully-connected layers).
        let conv = (1e9, 1e5);
        let fc = (1e6, 4e6);
        assert!(!t.memory_bound(conv.0, conv.1));
        assert!(t.memory_bound(fc.0, fc.1));
    }
}
