//! Reward layer: NetScore extrinsic reward (Eq. 2) with the §3.3 protocol
//! presets, and the Roofline hardware model its β/γ terms come from.

pub mod netscore;
pub mod roofline;

pub use netscore::NetScore;
pub use roofline::Roofline;
