//! Bench: miniature Table-2/Table-3 row generation — a complete
//! (search → best config → cost audit) cell per mode at reduced episode
//! count, timing what `autoq repro table2/table3` pays per row.

use autoq::coordinator::Coordinator;
use autoq::cost::Mode;
use autoq::data::synth::SynthDataset;
use autoq::search::{run_search, Granularity, Protocol, SearchConfig};
use autoq::util::bench::bench;

fn main() -> anyhow::Result<()> {
    println!("== table_rows bench (Table 2 quant / Table 3 binar cells) ==");
    let mut coord = Coordinator::open_default()?;
    let runner = coord.fresh_runner("cif10")?;
    let data = SynthDataset::new(42);
    let rt = coord.runtime();
    for mode in [Mode::Quant, Mode::Binar] {
        for gran in [Granularity::Network(5), Granularity::Layer, Granularity::Channel] {
            let mut cfg = SearchConfig::quick(mode, Protocol::accuracy_guaranteed(), gran);
            cfg.episodes = 4;
            cfg.warmup = 2;
            cfg.eval_batches = 1;
            let label = format!("cell cif10-{} {} (4 episodes)", gran.tag(), mode.as_str());
            bench(&label, 0, 2, || run_search(&mut *rt, &runner, &data, &cfg).unwrap());
        }
    }
    Ok(())
}
