//! Bench: reference-backend `eval_config` throughput at 1/2/4 worker
//! threads plus blocked-vs-naive matmul kernels — the two layers the
//! search loop's wall-clock hangs off.
//!
//! Flags (after `--`):
//!   --smoke        1 measured iteration on a short schedule; also asserts
//!                  serial/parallel byte-identity (the CI regression guard)
//!   --json PATH    write machine-readable results (the committed baseline
//!                  lives at BENCH_reference_eval.json in the repo root)
//!   --simd on|off  pin the SIMD integer-dot dispatch for the whole run
//!                  (default: the build's feature default); the dedicated
//!                  SIMD comparison section still measures both settings
//!
//! Full (non-smoke) runs enforce the scaling target from the ROADMAP: the
//! 4-thread eval sweep must reach ≥ 2× the serial throughput, or the
//! bench exits non-zero.  The check is skipped (with a warning) on hosts
//! with fewer than 4 cores, where the target is unmeasurable.  They also
//! enforce the integer-kernel floors: int8/int4 qgemm vs blocked f32, the
//! int depthwise conv vs its f32 kernel, and — on AVX2 hosts with the
//! `simd` feature — the SIMD int8 inner loop vs the scalar one (≥ 1.5×).
//!
//! Regenerate the baseline with:
//!   cargo bench --bench reference_eval -- --json ../BENCH_reference_eval.json

use std::path::PathBuf;

use autoq::coordinator::{Coordinator, JobSpec};
use autoq::cost::Mode;
use autoq::search::Granularity;
use autoq::data::synth::SynthDataset;
use autoq::data::Split;
use autoq::runtime::reference::kernels;
use autoq::runtime::{BackendKind, Parallelism};
use autoq::util::bench::bench;
use autoq::util::json::Json;
use autoq::util::rng::Rng;

const MODEL: &str = "cif10";
const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// Scaling target the full bench enforces: speedup_vs_serial at
/// `TARGET_THREADS` threads must reach `TARGET_SPEEDUP` (ROADMAP: "≥2× @
/// 4-thread").
const TARGET_THREADS: usize = 4;
const TARGET_SPEEDUP: f64 = 2.0;

/// Int-vs-f32 kernel targets (speedup of the quantize+qgemm call over the
/// blocked f32 matmul, same shape).  Full runs enforce the real targets;
/// smoke's single short iteration is too noisy to grade a speedup, so it
/// only guards against catastrophic slowdowns (e.g. a scalar fallback
/// accidentally taking over the int path).
const INT8_MIN_SPEEDUP: f64 = 1.2;
const INT4_MIN_SPEEDUP: f64 = 1.0;
const INT_SMOKE_MIN_SPEEDUP: f64 = 0.25;

/// SIMD-vs-scalar floor for the int8 qgemm inner loop (full runs on hosts
/// where the AVX2 path can actually engage; smoke runs only report).
const SIMD_INT8_MIN_SPEEDUP: f64 = 1.5;

/// Int-vs-f32 depthwise conv floors (same grading split as the qgemm
/// targets: real floor on full runs, catastrophe guard on smoke).
const DWCONV_MIN_SPEEDUP: f64 = 1.0;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path: Option<PathBuf> = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from);
    let simd_arg: Option<&str> = args
        .iter()
        .position(|a| a == "--simd")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str);
    match simd_arg {
        None => {}
        Some("on") => {
            kernels::set_simd_int_enabled(true);
        }
        Some("off") => {
            kernels::set_simd_int_enabled(false);
        }
        Some(other) => anyhow::bail!("--simd must be on|off, got {other:?}"),
    }
    // Whether the AVX2 integer dots can actually engage on this build/host
    // (the enable switch alone is not enough — see kernels::simd docs).
    #[cfg(target_arch = "x86_64")]
    let simd_capable = cfg!(feature = "simd") && std::arch::is_x86_feature_detected!("avx2");
    #[cfg(not(target_arch = "x86_64"))]
    let simd_capable = false;
    let (n_batches, iters, warmup) = if smoke { (2, 1, 0) } else { (4, 5, 1) };
    println!(
        "== reference_eval bench (threads sweep + kernel comparison; simd int dispatch {}) ==",
        if kernels::simd_int_enabled() { "on" } else { "off" }
    );

    // Shared short-pretrained params in a scratch artifact dir so every
    // runtime below evaluates the same model.
    let dir = std::env::temp_dir().join(format!("autoq_bench_refeval_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    {
        let mut coord = Coordinator::open_with_opts(&dir, Some(BackendKind::Reference), None)?;
        let steps = if smoke { 2 } else { 40 };
        coord.run(&JobSpec::pretrain(MODEL).steps(steps).build()?)?;
    }

    let data = SynthDataset::new(42);
    let mut rows: Vec<Json> = Vec::new();
    let mut baseline: Option<f64> = None;
    let mut reference_result: Option<(u64, u64)> = None;
    let mut target_speedup: Option<f64> = None;
    for &threads in &THREAD_COUNTS {
        let mut coord = Coordinator::open_with_opts(
            &dir,
            Some(BackendKind::Reference),
            Some(Parallelism::new(threads)),
        )?;
        let runner = coord.fresh_runner(MODEL)?;
        let wbits = vec![5u8; runner.meta.w_channels];
        let abits = vec![5u8; runner.meta.a_channels];
        let images = n_batches * runner.meta.eval_batch;
        let rt = coord.runtime();
        let mut last = None;
        let mut eval = || {
            runner
                .eval_config(&mut *rt, Mode::Quant, &wbits, &abits, &data, Split::Val, n_batches)
                .unwrap()
        };
        let r = bench(
            &format!("eval_config {MODEL} quant threads={threads} ({images} imgs)"),
            warmup,
            iters,
            || last = Some(eval()),
        );
        // Byte-identity guard: every thread count must reproduce the
        // serial result exactly.
        let res = last.expect("bench ran at least once");
        let bits = (res.accuracy.to_bits(), res.loss.to_bits());
        match reference_result {
            None => reference_result = Some(bits),
            Some(expect) => assert_eq!(
                bits, expect,
                "threads={threads} changed eval results — determinism contract broken"
            ),
        }
        let ips = images as f64 / r.mean_s;
        println!("    -> {ips:.1} images/sec");
        let speedup = match baseline {
            None => {
                baseline = Some(r.mean_s);
                1.0
            }
            Some(serial) => serial / r.mean_s,
        };
        if threads == TARGET_THREADS {
            target_speedup = Some(speedup);
        }
        rows.push(Json::obj(vec![
            ("threads", Json::from(threads)),
            ("batches", Json::from(n_batches)),
            ("images", Json::from(images)),
            ("mean_s", Json::from(r.mean_s)),
            ("min_s", Json::from(r.min_s)),
            ("images_per_sec", Json::from(ips)),
            ("speedup_vs_serial", Json::from(speedup)),
        ]));
    }

    // Kernel layer: blocked vs naive matmul on an im2col-shaped problem
    // (m = 32·32 output pixels, k = 3·3·64 patch, n = 128 filters).
    let (m, k, n) = if smoke { (64, 96, 48) } else { (1024, 576, 128) };
    let mut rng = Rng::new(5);
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    rng.fill_normal_f32(&mut a, 1.0);
    rng.fill_normal_f32(&mut b, 1.0);
    let kiters = if smoke { 1 } else { 20 };
    let rb = bench(&format!("matmul blocked ({m}x{k}x{n})"), warmup, kiters, || {
        kernels::matmul(&a, &b, m, k, n)
    });
    let rn = bench(&format!("matmul naive   ({m}x{k}x{n})"), warmup, kiters, || {
        let mut c = vec![0.0f32; m * n];
        kernels::naive::matmul_acc(&mut c, &a, &b, m, k, n);
        c
    });
    let flops = 2.0 * (m * k * n) as f64;
    println!(
        "    -> blocked {:.2} GFLOP/s vs naive {:.2} GFLOP/s",
        flops / rb.min_s / 1e9,
        flops / rn.min_s / 1e9
    );

    // Integer kernels vs the blocked f32 path, same shape.  Weights are
    // pre-quantized outside the timer (the runtime quantizes them once per
    // dispatch on either path); the dynamic per-row activation quantize
    // runs inside it (the int path pays it on every call).
    let bits8 = vec![8.0f32; n];
    let bits4 = vec![4.0f32; n];
    let (qw8, sw8) = kernels::quantize_weights_alloc(&b, k, n, &bits8, kernels::WRep::I8);
    let (qw4, sw4) = kernels::quantize_weights_alloc(&b, k, n, &bits4, kernels::WRep::I4);
    let mut qa = vec![0i8; m * k];
    let mut sa = vec![0.0f32; m];
    let mut oint = vec![0.0f32; m * n];
    let r8 = bench(&format!("qgemm int8     ({m}x{k}x{n})"), warmup, kiters, || {
        kernels::quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
        kernels::qgemm_into(&mut oint, &qa, &sa, &qw8, &sw8, m, k, n, false);
    });
    let r4 = bench(&format!("qgemm int4     ({m}x{k}x{n})"), warmup, kiters, || {
        kernels::quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
        kernels::qgemm_into(&mut oint, &qa, &sa, &qw4, &sw4, m, k, n, true);
    });
    let s8 = rb.min_s / r8.min_s;
    let s4 = rb.min_s / r4.min_s;
    println!("    -> int8 {s8:.2}x, int4 {s4:.2}x vs blocked f32");
    let (min8, min4) = if smoke {
        (INT_SMOKE_MIN_SPEEDUP, INT_SMOKE_MIN_SPEEDUP)
    } else {
        (INT8_MIN_SPEEDUP, INT4_MIN_SPEEDUP)
    };
    anyhow::ensure!(
        s8 >= min8 && s4 >= min4,
        "integer-kernel regression: int8 {s8:.2}x / int4 {s4:.2}x vs blocked f32 \
         (thresholds {min8}x / {min4}x)"
    );

    // SIMD-vs-scalar comparison on the int8 GEMM proper (activations
    // pre-quantized outside the timer, isolating the inner dot loops).
    // Results are bit-identical both ways — that contract is pinned by
    // tests; here only the speedup is graded.
    kernels::quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
    let prev_simd = kernels::set_simd_int_enabled(false);
    let r8_scalar = bench(&format!("qgemm int8 simd=off ({m}x{k}x{n})"), warmup, kiters, || {
        kernels::qgemm_into(&mut oint, &qa, &sa, &qw8, &sw8, m, k, n, false);
    });
    kernels::set_simd_int_enabled(true);
    let r8_simd = bench(&format!("qgemm int8 simd=on  ({m}x{k}x{n})"), warmup, kiters, || {
        kernels::qgemm_into(&mut oint, &qa, &sa, &qw8, &sw8, m, k, n, false);
    });
    kernels::set_simd_int_enabled(prev_simd);
    let simd_speedup = r8_scalar.min_s / r8_simd.min_s;
    println!(
        "    -> simd int8 {simd_speedup:.2}x vs scalar ({})",
        if simd_capable { "AVX2 active" } else { "AVX2 unavailable — dispatch is scalar both ways" }
    );
    if !simd_capable {
        println!(
            "note: SIMD int path cannot engage here (needs the `simd` feature and an \
             AVX2 x86_64 host) — skipping the >= {SIMD_INT8_MIN_SPEEDUP}x check"
        );
    } else if !smoke {
        anyhow::ensure!(
            simd_speedup >= SIMD_INT8_MIN_SPEEDUP,
            "SIMD integer-dot regression: {simd_speedup:.2}x vs scalar \
             (threshold {SIMD_INT8_MIN_SPEEDUP}x)"
        );
    }

    // Depthwise conv: int per-channel kernel vs the f32 kernel, same
    // shape (the layer class the int path previously excluded).
    use autoq::runtime::reference::nn::{self, Dims};
    let dd = if smoke {
        Dims { n: 1, h: 16, w: 16, c: 32 }
    } else {
        Dims { n: 2, h: 32, w: 32, c: 64 }
    };
    let (dk, ds) = (3usize, 1usize);
    let mut dw = vec![0.0f32; dk * dk * dd.c];
    let mut dx = vec![0.0f32; dd.elems()];
    rng.fill_normal_f32(&mut dw, 1.0);
    rng.fill_normal_f32(&mut dx, 1.0);
    // (k,k,1,cin) row-major is a (rest = k², cout = cin) weight — the
    // shared WQ quantizer covers it unchanged.
    let dbits = vec![8.0f32; dd.c];
    let (qdw, sdw) = kernels::quantize_weights_alloc(&dw, dk * dk, dd.c, &dbits, kernels::WRep::I8);
    let mut dout = vec![0.0f32; dd.elems()];
    let mut dqx = vec![0i8; dd.elems()];
    let mut dsx = vec![0.0f32; nn::dwconv_qrows(dd)];
    let label = format!("{}x{}x{}x{} k{dk}", dd.n, dd.h, dd.w, dd.c);
    let rdf = bench(&format!("dwconv f32     ({label})"), warmup, kiters, || {
        nn::dwconv2d_into(&dx, dd, &dw, dk, ds, &mut dout);
    });
    let rdi = bench(&format!("dwconv int8    ({label})"), warmup, kiters, || {
        nn::qdwconv2d_into(&dx, dd, &qdw, &sdw, false, dk, ds, &mut dout, &mut dqx, &mut dsx, None);
    });
    let sdw_speedup = rdf.min_s / rdi.min_s;
    println!("    -> int8 dwconv {sdw_speedup:.2}x vs f32");
    let dw_min = if smoke { INT_SMOKE_MIN_SPEEDUP } else { DWCONV_MIN_SPEEDUP };
    anyhow::ensure!(
        sdw_speedup >= dw_min,
        "int-dwconv regression: {sdw_speedup:.2}x vs f32 (threshold {dw_min}x)"
    );

    // Durable-checkpoint overhead: the same short search with snapshots
    // off, then at the tightest cadence (a snapshot after every episode —
    // real runs checkpoint far less often).  Full runs enforce the
    // DESIGN.md budget: journaling costs <= CKPT_MAX_OVERHEAD of search
    // wall-clock.  Smoke's single iteration only guards catastrophe (and
    // both grades require the checkpointed report to stay byte-identical
    // to the plain one — snapshots must never perturb results).
    let spec = JobSpec::search(MODEL)
        .granularity(Granularity::Network(4))
        .episodes(if smoke { 2 } else { 6 })
        .warmup(1)
        .eval_batches(1)
        .seed(11)
        .build()?;
    let canon = |j: &Json| {
        let mut j = j.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("secs".to_string(), Json::Num(0.0));
        }
        j.to_string()
    };
    let mut coord = Coordinator::open_with_opts(
        &dir,
        Some(BackendKind::Reference),
        Some(Parallelism::new(2)),
    )?;
    let siters = if smoke { 1 } else { 3 };
    coord.set_checkpoint_every(0);
    let mut plain = None;
    let rplain = bench("search checkpoint=off", warmup, siters, || {
        plain = Some(coord.run(&spec).unwrap().to_json());
    });
    coord.set_checkpoint_every(1);
    let mut ckpt = None;
    let rckpt = bench("search checkpoint=1 ", warmup, siters, || {
        ckpt = Some(coord.run(&spec).unwrap().to_json());
    });
    let ckpt_overhead = rckpt.min_s / rplain.min_s - 1.0;
    println!("    -> checkpoint overhead {:.2}% of search wall-clock", ckpt_overhead * 100.0);
    assert_eq!(
        canon(&plain.expect("plain search ran")),
        canon(&ckpt.expect("checkpointed search ran")),
        "a checkpointed search changed its report — snapshots must be side-effect free"
    );
    const CKPT_MAX_OVERHEAD: f64 = 0.02;
    if smoke {
        anyhow::ensure!(
            rckpt.min_s <= rplain.min_s * 2.0,
            "checkpointing catastrophically slowed the smoke search \
             ({:.3}s vs {:.3}s)",
            rckpt.min_s,
            rplain.min_s
        );
    } else {
        anyhow::ensure!(
            ckpt_overhead <= CKPT_MAX_OVERHEAD,
            "journal overhead regression: {:.2}% of search wall-clock \
             (budget {:.0}%)",
            ckpt_overhead * 100.0,
            CKPT_MAX_OVERHEAD * 100.0
        );
    }

    if let Some(path) = json_path {
        let doc = Json::obj(vec![
            ("bench", Json::Str("reference_eval".to_string())),
            ("model", Json::Str(MODEL.to_string())),
            ("smoke", Json::Bool(smoke)),
            ("target_threads", Json::from(TARGET_THREADS)),
            ("target_speedup", Json::from(TARGET_SPEEDUP)),
            ("eval", Json::Arr(rows)),
            (
                "matmul",
                Json::obj(vec![
                    ("m", Json::from(m)),
                    ("k", Json::from(k)),
                    ("n", Json::from(n)),
                    ("blocked_min_s", Json::from(rb.min_s)),
                    ("naive_min_s", Json::from(rn.min_s)),
                    ("blocked_gflops", Json::from(flops / rb.min_s / 1e9)),
                    ("naive_gflops", Json::from(flops / rn.min_s / 1e9)),
                ]),
            ),
            (
                "qgemm",
                Json::obj(vec![
                    ("f32_min_s", Json::from(rb.min_s)),
                    ("i8_min_s", Json::from(r8.min_s)),
                    ("i4_min_s", Json::from(r4.min_s)),
                    ("i8_speedup", Json::from(s8)),
                    ("i4_speedup", Json::from(s4)),
                    ("i8_threshold", Json::from(min8)),
                    ("i4_threshold", Json::from(min4)),
                ]),
            ),
            (
                "simd",
                Json::obj(vec![
                    ("capable", Json::Bool(simd_capable)),
                    (
                        "forced",
                        match simd_arg {
                            Some(s) => Json::Str(s.to_string()),
                            None => Json::Null,
                        },
                    ),
                    ("i8_scalar_min_s", Json::from(r8_scalar.min_s)),
                    ("i8_simd_min_s", Json::from(r8_simd.min_s)),
                    ("i8_speedup", Json::from(simd_speedup)),
                    ("i8_threshold", Json::from(SIMD_INT8_MIN_SPEEDUP)),
                ]),
            ),
            (
                "checkpoint",
                Json::obj(vec![
                    ("plain_min_s", Json::from(rplain.min_s)),
                    ("ckpt_min_s", Json::from(rckpt.min_s)),
                    ("overhead", Json::from(ckpt_overhead)),
                    ("threshold", Json::from(0.02)),
                ]),
            ),
            (
                "dwconv",
                Json::obj(vec![
                    ("n", Json::from(dd.n)),
                    ("h", Json::from(dd.h)),
                    ("w", Json::from(dd.w)),
                    ("c", Json::from(dd.c)),
                    ("k", Json::from(dk)),
                    ("f32_min_s", Json::from(rdf.min_s)),
                    ("i8_min_s", Json::from(rdi.min_s)),
                    ("i8_speedup", Json::from(sdw_speedup)),
                    ("i8_threshold", Json::from(dw_min)),
                ]),
            ),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {}", path.display());
    }
    std::fs::remove_dir_all(&dir).ok();

    // Scaling-target gate (full runs only — smoke's single short
    // iteration is too noisy to grade, and a host without TARGET_THREADS
    // cores cannot express the target at all).
    if !smoke {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let measured = target_speedup.expect("thread sweep covered the target count");
        if cores < TARGET_THREADS {
            println!(
                "note: host has {cores} core(s) < {TARGET_THREADS} — skipping the \
                 >= {TARGET_SPEEDUP}x scaling check (measured {measured:.2}x)"
            );
        } else {
            anyhow::ensure!(
                measured >= TARGET_SPEEDUP,
                "scaling regression: {measured:.2}x at {TARGET_THREADS} threads \
                 (target >= {TARGET_SPEEDUP}x)"
            );
            println!(
                "scaling target met: {measured:.2}x at {TARGET_THREADS} threads \
                 (target >= {TARGET_SPEEDUP}x)"
            );
        }
    }
    Ok(())
}
