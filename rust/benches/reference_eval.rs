//! Bench: reference-backend `eval_config` throughput at 1/2/4 worker
//! threads plus blocked-vs-naive matmul kernels — the two layers the
//! search loop's wall-clock hangs off.
//!
//! Flags (after `--`):
//!   --smoke        1 measured iteration on a short schedule; also asserts
//!                  serial/parallel byte-identity (the CI regression guard)
//!   --json PATH    write machine-readable results (the committed baseline
//!                  lives at BENCH_reference_eval.json in the repo root)
//!
//! Full (non-smoke) runs enforce the scaling target from the ROADMAP: the
//! 4-thread eval sweep must reach ≥ 2× the serial throughput, or the
//! bench exits non-zero.  The check is skipped (with a warning) on hosts
//! with fewer than 4 cores, where the target is unmeasurable.
//!
//! Regenerate the baseline with:
//!   cargo bench --bench reference_eval -- --json ../BENCH_reference_eval.json

use std::path::PathBuf;

use autoq::coordinator::{Coordinator, JobSpec};
use autoq::cost::Mode;
use autoq::data::synth::SynthDataset;
use autoq::data::Split;
use autoq::runtime::reference::kernels;
use autoq::runtime::{BackendKind, Parallelism};
use autoq::util::bench::bench;
use autoq::util::json::Json;
use autoq::util::rng::Rng;

const MODEL: &str = "cif10";
const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// Scaling target the full bench enforces: speedup_vs_serial at
/// `TARGET_THREADS` threads must reach `TARGET_SPEEDUP` (ROADMAP: "≥2× @
/// 4-thread").
const TARGET_THREADS: usize = 4;
const TARGET_SPEEDUP: f64 = 2.0;

/// Int-vs-f32 kernel targets (speedup of the quantize+qgemm call over the
/// blocked f32 matmul, same shape).  Full runs enforce the real targets;
/// smoke's single short iteration is too noisy to grade a speedup, so it
/// only guards against catastrophic slowdowns (e.g. a scalar fallback
/// accidentally taking over the int path).
const INT8_MIN_SPEEDUP: f64 = 1.2;
const INT4_MIN_SPEEDUP: f64 = 1.0;
const INT_SMOKE_MIN_SPEEDUP: f64 = 0.25;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path: Option<PathBuf> = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from);
    let (n_batches, iters, warmup) = if smoke { (2, 1, 0) } else { (4, 5, 1) };
    println!("== reference_eval bench (threads sweep + kernel comparison) ==");

    // Shared short-pretrained params in a scratch artifact dir so every
    // runtime below evaluates the same model.
    let dir = std::env::temp_dir().join(format!("autoq_bench_refeval_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    {
        let mut coord = Coordinator::open_with_opts(&dir, Some(BackendKind::Reference), None)?;
        let steps = if smoke { 2 } else { 40 };
        coord.run(&JobSpec::pretrain(MODEL).steps(steps).build()?)?;
    }

    let data = SynthDataset::new(42);
    let mut rows: Vec<Json> = Vec::new();
    let mut baseline: Option<f64> = None;
    let mut reference_result: Option<(u64, u64)> = None;
    let mut target_speedup: Option<f64> = None;
    for &threads in &THREAD_COUNTS {
        let mut coord = Coordinator::open_with_opts(
            &dir,
            Some(BackendKind::Reference),
            Some(Parallelism::new(threads)),
        )?;
        let runner = coord.fresh_runner(MODEL)?;
        let wbits = vec![5u8; runner.meta.w_channels];
        let abits = vec![5u8; runner.meta.a_channels];
        let images = n_batches * runner.meta.eval_batch;
        let rt = coord.runtime();
        let mut last = None;
        let mut eval = || {
            runner
                .eval_config(&mut *rt, Mode::Quant, &wbits, &abits, &data, Split::Val, n_batches)
                .unwrap()
        };
        let r = bench(
            &format!("eval_config {MODEL} quant threads={threads} ({images} imgs)"),
            warmup,
            iters,
            || last = Some(eval()),
        );
        // Byte-identity guard: every thread count must reproduce the
        // serial result exactly.
        let res = last.expect("bench ran at least once");
        let bits = (res.accuracy.to_bits(), res.loss.to_bits());
        match reference_result {
            None => reference_result = Some(bits),
            Some(expect) => assert_eq!(
                bits, expect,
                "threads={threads} changed eval results — determinism contract broken"
            ),
        }
        let ips = images as f64 / r.mean_s;
        println!("    -> {ips:.1} images/sec");
        let speedup = match baseline {
            None => {
                baseline = Some(r.mean_s);
                1.0
            }
            Some(serial) => serial / r.mean_s,
        };
        if threads == TARGET_THREADS {
            target_speedup = Some(speedup);
        }
        rows.push(Json::obj(vec![
            ("threads", Json::from(threads)),
            ("batches", Json::from(n_batches)),
            ("images", Json::from(images)),
            ("mean_s", Json::from(r.mean_s)),
            ("min_s", Json::from(r.min_s)),
            ("images_per_sec", Json::from(ips)),
            ("speedup_vs_serial", Json::from(speedup)),
        ]));
    }

    // Kernel layer: blocked vs naive matmul on an im2col-shaped problem
    // (m = 32·32 output pixels, k = 3·3·64 patch, n = 128 filters).
    let (m, k, n) = if smoke { (64, 96, 48) } else { (1024, 576, 128) };
    let mut rng = Rng::new(5);
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    rng.fill_normal_f32(&mut a, 1.0);
    rng.fill_normal_f32(&mut b, 1.0);
    let kiters = if smoke { 1 } else { 20 };
    let rb = bench(&format!("matmul blocked ({m}x{k}x{n})"), warmup, kiters, || {
        kernels::matmul(&a, &b, m, k, n)
    });
    let rn = bench(&format!("matmul naive   ({m}x{k}x{n})"), warmup, kiters, || {
        let mut c = vec![0.0f32; m * n];
        kernels::naive::matmul_acc(&mut c, &a, &b, m, k, n);
        c
    });
    let flops = 2.0 * (m * k * n) as f64;
    println!(
        "    -> blocked {:.2} GFLOP/s vs naive {:.2} GFLOP/s",
        flops / rb.min_s / 1e9,
        flops / rn.min_s / 1e9
    );

    // Integer kernels vs the blocked f32 path, same shape.  Weights are
    // pre-quantized outside the timer (the runtime quantizes them once per
    // dispatch on either path); the dynamic per-row activation quantize
    // runs inside it (the int path pays it on every call).
    let bits8 = vec![8.0f32; n];
    let bits4 = vec![4.0f32; n];
    let (qw8, sw8) = kernels::quantize_weights_alloc(&b, k, n, &bits8, kernels::WRep::I8);
    let (qw4, sw4) = kernels::quantize_weights_alloc(&b, k, n, &bits4, kernels::WRep::I4);
    let mut qa = vec![0i8; m * k];
    let mut sa = vec![0.0f32; m];
    let mut oint = vec![0.0f32; m * n];
    let r8 = bench(&format!("qgemm int8     ({m}x{k}x{n})"), warmup, kiters, || {
        kernels::quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
        kernels::qgemm_into(&mut oint, &qa, &sa, &qw8, &sw8, m, k, n, false);
    });
    let r4 = bench(&format!("qgemm int4     ({m}x{k}x{n})"), warmup, kiters, || {
        kernels::quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
        kernels::qgemm_into(&mut oint, &qa, &sa, &qw4, &sw4, m, k, n, true);
    });
    let s8 = rb.min_s / r8.min_s;
    let s4 = rb.min_s / r4.min_s;
    println!("    -> int8 {s8:.2}x, int4 {s4:.2}x vs blocked f32");
    let (min8, min4) = if smoke {
        (INT_SMOKE_MIN_SPEEDUP, INT_SMOKE_MIN_SPEEDUP)
    } else {
        (INT8_MIN_SPEEDUP, INT4_MIN_SPEEDUP)
    };
    anyhow::ensure!(
        s8 >= min8 && s4 >= min4,
        "integer-kernel regression: int8 {s8:.2}x / int4 {s4:.2}x vs blocked f32 \
         (thresholds {min8}x / {min4}x)"
    );

    if let Some(path) = json_path {
        let doc = Json::obj(vec![
            ("bench", Json::Str("reference_eval".to_string())),
            ("model", Json::Str(MODEL.to_string())),
            ("smoke", Json::Bool(smoke)),
            ("target_threads", Json::from(TARGET_THREADS)),
            ("target_speedup", Json::from(TARGET_SPEEDUP)),
            ("eval", Json::Arr(rows)),
            (
                "matmul",
                Json::obj(vec![
                    ("m", Json::from(m)),
                    ("k", Json::from(k)),
                    ("n", Json::from(n)),
                    ("blocked_min_s", Json::from(rb.min_s)),
                    ("naive_min_s", Json::from(rn.min_s)),
                    ("blocked_gflops", Json::from(flops / rb.min_s / 1e9)),
                    ("naive_gflops", Json::from(flops / rn.min_s / 1e9)),
                ]),
            ),
            (
                "qgemm",
                Json::obj(vec![
                    ("f32_min_s", Json::from(rb.min_s)),
                    ("i8_min_s", Json::from(r8.min_s)),
                    ("i4_min_s", Json::from(r4.min_s)),
                    ("i8_speedup", Json::from(s8)),
                    ("i4_speedup", Json::from(s4)),
                    ("i8_threshold", Json::from(min8)),
                    ("i4_threshold", Json::from(min4)),
                ]),
            ),
        ]);
        std::fs::write(&path, format!("{doc}\n"))?;
        println!("wrote {}", path.display());
    }
    std::fs::remove_dir_all(&dir).ok();

    // Scaling-target gate (full runs only — smoke's single short
    // iteration is too noisy to grade, and a host without TARGET_THREADS
    // cores cannot express the target at all).
    if !smoke {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let measured = target_speedup.expect("thread sweep covered the target count");
        if cores < TARGET_THREADS {
            println!(
                "note: host has {cores} core(s) < {TARGET_THREADS} — skipping the \
                 >= {TARGET_SPEEDUP}x scaling check (measured {measured:.2}x)"
            );
        } else {
            anyhow::ensure!(
                measured >= TARGET_SPEEDUP,
                "scaling regression: {measured:.2}x at {TARGET_THREADS} threads \
                 (target >= {TARGET_SPEEDUP}x)"
            );
            println!(
                "scaling target met: {measured:.2}x at {TARGET_THREADS} threads \
                 (target >= {TARGET_SPEEDUP}x)"
            );
        }
    }
    Ok(())
}
