//! Bench: one full search episode — hierarchical (AutoQ) vs flat DDPG —
//! the end-to-end unit behind Tables 2-4 and Fig 8.  Reports wall-clock
//! per episode so paper-scale (400-episode) cost is directly computable.

use autoq::agent::hiro::{HiroAgent, HiroConfig};
use autoq::baselines::{run_baseline, BaselineConfig, BaselinePolicy};
use autoq::coordinator::Coordinator;
use autoq::cost::Mode;
use autoq::data::synth::SynthDataset;
use autoq::env::state::StateBuilder;
use autoq::search::episode::{run_episode, EpisodeConfig};
use autoq::search::{Granularity, Protocol};
use autoq::util::bench::bench;

fn main() -> anyhow::Result<()> {
    println!("== search_episode bench (Tables 2-4 / Fig 8 unit) ==");
    let mut coord = Coordinator::open_default()?;
    let runner = coord.fresh_runner("cif10")?;
    let data = SynthDataset::new(42);
    let wvar = runner.weight_variances();
    let sb = StateBuilder::new(&runner.meta, &wvar);
    let protocol = Protocol::accuracy_guaranteed();
    let ep_cfg = EpisodeConfig { eval_batches: 1, ..EpisodeConfig::default() };
    let rt = coord.runtime();

    let mut agents = HiroAgent::new(&*rt, HiroConfig::default(), 1)?;
    bench("hiro episode (cif10 channel, 1 eval batch)", 1, 4, || {
        run_episode(
            &mut *rt, &runner, &sb, &wvar, &mut agents, &protocol,
            Granularity::Channel, Mode::Quant, &data, &ep_cfg,
        )
        .unwrap()
    });
    bench("hiro episode (cif10 layer granularity)", 1, 4, || {
        run_episode(
            &mut *rt, &runner, &sb, &wvar, &mut agents, &protocol,
            Granularity::Layer, Mode::Quant, &data, &ep_cfg,
        )
        .unwrap()
    });

    // Flat DDPG baseline: whole short search (episodes amortized).
    let mut bcfg = BaselineConfig::quick(BaselinePolicy::FlatDdpg, Mode::Quant, protocol);
    bcfg.episodes = 3;
    bcfg.warmup = 3;
    bcfg.eval_batches = 1;
    bench("flat-ddpg 3-episode search (cif10)", 0, 2, || {
        run_baseline(&mut *rt, &runner, &data, &bcfg).unwrap()
    });

    println!("\nper-executable stats:\n{}", rt.stats_report());
    Ok(())
}
