//! Bench: the DDPG hot path — actor dispatch (one per channel per episode)
//! and the fused update step (hundreds per episode).  These dominate Fig-8
//! search wall-clock, so they are the L3 optimization target.

use autoq::agent::{DdpgAgent, DdpgHyper, ReplayBuffer, Transition};
use autoq::runtime::Runtime;
use autoq::util::bench::bench;
use autoq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    println!("== agent_step bench (search-loop hot path) ==");
    let mut rt = Runtime::open_default()?;
    let mut rng = Rng::new(1);
    let meta16 = rt.manifest.agent(16)?.clone();
    let agent = DdpgAgent::new(meta16.clone(), DdpgHyper::default(), &mut rng);

    let state = vec![0.3f32; 16];
    bench("ddpg act_one (s16)", 5, 200, || {
        agent.act_one(&mut rt, &state).unwrap()
    });
    let states128 = vec![0.3f32; 128 * 16];
    bench("ddpg act batched (128 states)", 5, 200, || {
        agent.act(&mut rt, &states128, 128).unwrap()
    });

    let mut replay = ReplayBuffer::new(2000);
    for i in 0..512 {
        replay.push(Transition {
            s: vec![i as f32 / 512.0; 16],
            a: (i % 32) as f32,
            r: 0.1,
            s2: vec![(i + 1) as f32 / 512.0; 16],
            done: i % 50 == 0,
        });
    }
    let mut agent2 = DdpgAgent::new(meta16, DdpgHyper::default(), &mut rng);
    bench("ddpg update (batch 64, fused adam+targets)", 3, 100, || {
        agent2.update(&mut rt, &replay, &mut rng).unwrap()
    });
    Ok(())
}
