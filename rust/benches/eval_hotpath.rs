//! Bench: `eval_config` — the Tables 2/3 inner loop.  One call = one
//! validation batch through the AOT'd Pallas-quantized forward pass; every
//! search episode pays `eval_batches` of these.  Runners come from the
//! coordinator's model cache (pre-training on first use).

use autoq::coordinator::Coordinator;
use autoq::cost::Mode;
use autoq::data::synth::SynthDataset;
use autoq::data::Split;
use autoq::util::bench::bench;

fn main() -> anyhow::Result<()> {
    println!("== eval_hotpath bench (Tables 2/3 inner loop) ==");
    let mut coord = Coordinator::open_default()?;
    let data = SynthDataset::new(42);
    for model in ["cif10", "res18", "sqnet", "monet"] {
        let runner = coord.fresh_runner(model)?;
        let wbits = vec![5u8; runner.meta.w_channels];
        let abits = vec![5u8; runner.meta.a_channels];
        let rt = coord.runtime();
        for mode in [Mode::Quant, Mode::Binar] {
            bench(
                &format!("eval_config {model} {} (256 imgs)", mode.as_str()),
                1,
                5,
                || {
                    runner
                        .eval_config(&mut *rt, mode, &wbits, &abits, &data, Split::Val, 1)
                        .unwrap()
                },
            );
        }
    }
    println!("\nper-executable stats:\n{}", coord.runtime().stats_report());
    Ok(())
}
