//! Bench: Fig-1 hardware cost model + the m(N)/p(N) logic-op audit that
//! runs inside every reward evaluation (Tables 2-4 inner loop, L3 hot path).

use autoq::cost::hardware::{fig1_table, normalized_cost, Mode};
use autoq::cost::logic::model_cost;
use autoq::runtime::Manifest;
use autoq::util::bench::bench;

fn main() {
    println!("== cost_model bench (Fig 1 + NetScore cost audit) ==");
    bench("fig1_table(32)", 10, 1000, || fig1_table(32));
    bench("normalized_cost(quant 5x5)", 10, 1000, || {
        normalized_cost(Mode::Quant, 5, 5)
    });

    // Model-scale audit: real manifest when built, builtin zoo otherwise.
    let man = Manifest::load(std::path::Path::new("artifacts"))
        .unwrap_or_else(|_| autoq::runtime::reference::builtin_manifest());
    for model in ["cif10", "res18", "sqnet", "monet"] {
        let meta = man.model(model).unwrap();
        let wbits = vec![5u8; meta.w_channels];
        let abits = vec![5u8; meta.a_channels];
        let layers = meta.layers.clone();
        bench(&format!("model_cost({model})"), 10, 2000, || {
            model_cost(&layers, &wbits, &abits)
        });
    }
}
