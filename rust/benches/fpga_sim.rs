//! Bench: the cycle-level FPGA simulators behind Figs 9-12 — per-inference
//! simulation latency and the full fig-9 grid regeneration rate.

use autoq::cost::Mode;
use autoq::runtime::Manifest;
use autoq::sim::{Arch, FpgaSim};
use autoq::util::bench::bench;

fn main() {
    println!("== fpga_sim bench (Figs 9-12 substrate) ==");
    // Use real artifact metadata when present, the builtin zoo otherwise.
    let man = Manifest::load(std::path::Path::new("artifacts"))
        .unwrap_or_else(|_| autoq::runtime::reference::builtin_manifest());
    for model in ["res18", "monet"] {
        let meta = man.model(model).unwrap().clone();
        let wbits: Vec<u8> = (0..meta.w_channels).map(|i| 3 + (i % 4) as u8).collect();
        let abits: Vec<u8> = (0..meta.a_channels).map(|i| 3 + (i % 3) as u8).collect();
        for arch in [Arch::Temporal, Arch::Spatial] {
            for mode in [Mode::Quant, Mode::Binar] {
                let sim = FpgaSim::new(arch, mode);
                let layers = meta.layers.clone();
                let (w, a) = (wbits.clone(), abits.clone());
                bench(
                    &format!("sim {model} {} {}", arch.as_str(), mode.as_str()),
                    5,
                    500,
                    move || sim.run(&layers, &w, &a),
                );
            }
        }
    }
    // Whole fig-9 style grid (4 granularity rows × 2 modes × 2 archs).
    let meta = man.model("monet").unwrap().clone();
    bench("fig9 grid (monet, 16 sims)", 2, 100, || {
        let mut acc = 0.0;
        for mode in [Mode::Quant, Mode::Binar] {
            for arch in [Arch::Temporal, Arch::Spatial] {
                for bits in [32u8, 5, 4, 3] {
                    let sim = FpgaSim::new(arch, mode);
                    let w = vec![bits; meta.w_channels];
                    let a = vec![bits; meta.a_channels];
                    acc += sim.run(&meta.layers, &w, &a).fps;
                }
            }
        }
        acc
    });
}
