//! The content-addressed eval cache (`serve::cache`): key stability and
//! encoding, invalidation on every semantic field, the `ModelRunner`
//! memoization seam, and — the load-bearing contract — byte-identical
//! `JobReport` JSON between cached and uncached coordinator runs.

use std::path::{Path, PathBuf};

use autoq::coordinator::{Coordinator, JobSpec};
use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::{BackendKind, Parallelism, Runtime, RuntimeOpts};
use autoq::search::{Granularity, Protocol};
use autoq::serve::cache::{eval_key, CacheHandle};
use autoq::util::rng::Rng;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("autoq_cache_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn open_ref(dir: &Path) -> Runtime {
    let opts = RuntimeOpts { threads: Some(Parallelism::new(2)), ..Default::default() };
    Runtime::open_full(dir, BackendKind::Reference, opts).expect("runtime open")
}

/// Independent re-derivation of the documented key encoding (DESIGN.md
/// §Serve daemon): FNV-1a 64 over length-prefixed little-endian fields in
/// canonical order.  Rebuilding the hash from the byte layout — without
/// `KeyHasher` — proves the key is a pure function of the spec with no
/// per-process state (std's `DefaultHasher` would fail this by design),
/// i.e. the same spec hashes identically across processes and machines.
#[test]
fn key_encoding_is_pinned_and_process_independent() {
    let (backend, model, mode) = ("reference", "cif10", "quant");
    let (wbits, abits): (&[u8], &[u8]) = (&[5, 4, 3], &[4, 4]);
    let (data_seed, data_noise) = (42u64, 0.85f32);
    let (split, n_batches, eval_batch, param_fp) = ("val", 2usize, 256usize, 77u64);
    let calib_fp = 9u64;

    let mut bytes: Vec<u8> = Vec::new();
    let push_u64 = |bytes: &mut Vec<u8>, v: u64| bytes.extend_from_slice(&v.to_le_bytes());
    let push_str = |bytes: &mut Vec<u8>, s: &str| {
        push_u64(bytes, s.len() as u64);
        bytes.extend_from_slice(s.as_bytes());
    };
    let push_blob = |bytes: &mut Vec<u8>, b: &[u8]| {
        push_u64(bytes, b.len() as u64);
        bytes.extend_from_slice(b);
    };
    push_str(&mut bytes, backend);
    push_str(&mut bytes, model);
    push_str(&mut bytes, mode);
    push_blob(&mut bytes, wbits);
    push_blob(&mut bytes, abits);
    push_u64(&mut bytes, data_seed);
    push_u64(&mut bytes, data_noise.to_bits() as u64);
    push_str(&mut bytes, split);
    push_u64(&mut bytes, n_batches as u64);
    push_u64(&mut bytes, eval_batch as u64);
    push_u64(&mut bytes, param_fp);
    push_u64(&mut bytes, calib_fp);

    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }

    let key = eval_key(
        backend, model, mode, wbits, abits, data_seed, data_noise, split, n_batches,
        eval_batch, param_fp, calib_fp,
    );
    assert_eq!(h, key, "encoding drifted from the documented canonical form");
    // And the derivation is stable call-to-call.
    let again = eval_key(
        backend, model, mode, wbits, abits, data_seed, data_noise, split, n_batches,
        eval_batch, param_fp, calib_fp,
    );
    assert_eq!(key, again);
}

/// Every semantic field must invalidate: flipping any one input yields a
/// different key (bit-config, seeds, backend, split, batch schedule,
/// params).
#[test]
fn any_field_change_invalidates_the_key() {
    let base = || eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0);
    let variants: Vec<(&str, u64)> = vec![
        ("backend", eval_key("shard", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0)),
        ("model", eval_key("reference", "res18", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0)),
        ("mode", eval_key("reference", "cif10", "binar", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0)),
        ("wbits", eval_key("reference", "cif10", "quant", &[6, 4], &[4], 42, 0.85, "val", 2, 256, 77, 0)),
        ("abits", eval_key("reference", "cif10", "quant", &[5, 4], &[3], 42, 0.85, "val", 2, 256, 77, 0)),
        ("data_seed", eval_key("reference", "cif10", "quant", &[5, 4], &[4], 7, 0.85, "val", 2, 256, 77, 0)),
        ("split", eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "test", 2, 256, 77, 0)),
        ("n_batches", eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 4, 256, 77, 0)),
        ("param_fp", eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 78, 0)),
        ("calib_fp", eval_key("reference", "cif10", "quant", &[5, 4], &[4], 42, 0.85, "val", 2, 256, 77, 9)),
    ];
    for (field, v) in variants {
        assert_ne!(v, base(), "changing {field} must change the key");
    }
}

/// The `ModelRunner::eval_config` seam: identical evals hit, different
/// configs miss, mutated params miss — and a hit returns bit-identical
/// numbers to an uncached runner.
#[test]
fn eval_config_memoizes_through_the_runner_seam() {
    let dir = temp_dir("seam");
    let mut rt = open_ref(&dir);
    let meta = rt.manifest.model("cif10").unwrap().clone();
    let params = ParamStore::init(&meta.params, &mut Rng::new(42));
    let plain = ModelRunner::new(meta.clone(), params.clone()).unwrap();
    let mut runner = ModelRunner::new(meta, params).unwrap();
    let handle = CacheHandle::private();
    runner.set_eval_cache(Some(handle.clone()));

    let data = SynthDataset::new(42);
    let wbits = vec![5u8; runner.meta.w_channels];
    let abits = vec![4u8; runner.meta.a_channels];
    let eval = |r: &ModelRunner, rt: &mut Runtime, wb: &[u8]| {
        r.eval_config(rt, Mode::Quant, wb, &abits, &data, Split::Val, 2).unwrap()
    };

    let cold = eval(&runner, &mut rt, &wbits);
    assert_eq!(handle.counts(), (0, 1), "first eval must miss");
    let warm = eval(&runner, &mut rt, &wbits);
    assert_eq!(handle.counts(), (1, 1), "second identical eval must hit");
    assert_eq!(warm.accuracy.to_bits(), cold.accuracy.to_bits());
    assert_eq!(warm.loss.to_bits(), cold.loss.to_bits());
    assert_eq!(warm.images, cold.images);

    // A cache hit returns exactly what an uncached runner computes.
    let bare = eval(&plain, &mut rt, &wbits);
    assert_eq!(bare.accuracy.to_bits(), warm.accuracy.to_bits());
    assert_eq!(bare.loss.to_bits(), warm.loss.to_bits());

    // A different bit-config is a different content address.
    let wb6 = vec![6u8; runner.meta.w_channels];
    eval(&runner, &mut rt, &wb6);
    assert_eq!(handle.counts(), (1, 2), "new config must miss");

    // Mutating the weights changes the param fingerprint: the old entry
    // must not be served for the new weights.
    runner.params.tensors[0].data[0] += 0.5;
    runner.invalidate_param_cache();
    let retrained = eval(&runner, &mut rt, &wbits);
    assert_eq!(handle.counts(), (1, 3), "mutated params must miss");
    assert_ne!(
        (retrained.accuracy.to_bits(), retrained.loss.to_bits()),
        (cold.accuracy.to_bits(), cold.loss.to_bits()),
        "sanity: the mutation actually changed the eval"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The determinism contract end-to-end: a search on a cache-attached
/// coordinator produces byte-identical `JobReport` JSON to an uncached
/// one (wall-clock `secs` zeroed, as in tests/shard_backend.rs), and a
/// repeat of the same job is served with >0 hits.
#[test]
fn cached_search_reports_are_byte_identical_with_hits() {
    let dir = temp_dir("coord");
    // Persist cheap trained params once so every coordinator loads the
    // same bytes instead of auto-pretraining 300 steps.
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        coord.run(&JobSpec::pretrain("cif10").steps(3).build().unwrap()).unwrap();
    }
    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Network(5))
        .eval_batches(2)
        .seed(11)
        .build()
        .unwrap();
    let run = |coord: &mut Coordinator| {
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0;
        report.to_json().to_string()
    };

    let mut cold = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
    let want = run(&mut cold);
    assert!(want.contains("\"wbits\""), "sanity: report carries a config");

    let mut warm = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
    let handle = CacheHandle::private();
    warm.set_eval_cache(handle.clone());
    let first = run(&mut warm);
    let (h1, m1) = handle.counts();
    let second = run(&mut warm);
    let (h2, m2) = handle.counts();

    assert_eq!(first, want, "caching must not change report bytes (cold cache)");
    assert_eq!(second, want, "caching must not change report bytes (warm cache)");
    assert!(h2 > h1, "repeat of the same job must produce cache hits");
    assert!(m1 > 0, "sanity: the first run populated the cache");
    assert_eq!(m2, m1, "a byte-identical repeat must add no misses");
    std::fs::remove_dir_all(&dir).ok();
}
