//! Property tests over the deterministic substrate (`util::prop::forall`):
//! FPGA-simulator conservation laws, latency/energy monotonicity in the
//! bit-widths, bit-config persistence round-trips (JSON `SavedConfig`
//! vs the §3.4 6-bit packed form), and bit-exactness of the blocked
//! matmul kernels against their naive references.

use autoq::cost::logic::model_cost;
use autoq::cost::Mode;
use autoq::models::storage::{pack6, unpack6};
use autoq::quant::{load_config, save_config};
use autoq::runtime::reference::kernels;
use autoq::runtime::LayerMeta;
use autoq::search::{EpisodeOutcome, LayerBits};
use autoq::sim::{Arch, FpgaSim};
use autoq::util::prop::{forall, forall_ns, gen_bits_vec, shrink_vec};
use autoq::util::rng::Rng;

/// Random but self-consistent conv/dwconv/fc layer + per-channel bits.
fn gen_layer(r: &mut Rng) -> (LayerMeta, Vec<u8>, Vec<u8>) {
    let typ = match r.below(4) {
        0 => "fc",
        1 => "dwconv",
        _ => "conv",
    };
    let (k, s) = if typ == "fc" { (1, 1) } else { ([1usize, 3][r.below(2)], 1 + r.below(2)) };
    let cin = 1 + r.below(8);
    let cout = if typ == "dwconv" { cin } else { 1 + r.below(8) };
    let (h_in, w_in) = if typ == "fc" { (1, 1) } else { (4 + r.below(13), 4 + r.below(13)) };
    let h_out = (h_in + s - 1) / s;
    let w_out = (w_in + s - 1) / s;
    let macs = match typ {
        "fc" => (cin * cout) as u64,
        "dwconv" => (h_out * w_out * k * k * cin) as u64,
        _ => (h_out * w_out * k * k * cin * cout) as u64,
    };
    let a_len = if typ == "fc" { 1 } else { cin };
    let layer = LayerMeta {
        name: "lp_test".into(),
        typ: typ.into(),
        k,
        stride: s,
        cin,
        cout,
        h_in,
        w_in,
        h_out,
        w_out,
        macs,
        w_off: 0,
        w_len: cout,
        a_off: 0,
        a_len,
    };
    // Mostly live channels (≥1 bit via gen_bits_vec semantics), with
    // deliberate pruning sprinkled in to exercise the 0-bit path.
    let mut wbits: Vec<u8> = (0..cout).map(|_| 1 + r.below(8) as u8).collect();
    let mut abits: Vec<u8> = (0..a_len).map(|_| 1 + r.below(8) as u8).collect();
    if r.below(4) == 0 {
        wbits[r.below(cout)] = 0;
    }
    if r.below(8) == 0 {
        abits[r.below(a_len)] = 0;
    }
    (layer, wbits, abits)
}

#[test]
fn prop_fpga_layer_time_is_max_of_compute_and_dma() {
    // Double-buffered DMA: a single-layer model's total time must be
    // exactly max(compute, dma) — neither sum nor min.
    forall_ns(101, gen_layer, |(layer, wbits, abits)| {
        for arch in [Arch::Temporal, Arch::Spatial] {
            for mode in [Mode::Quant, Mode::Binar] {
                let rep = FpgaSim::new(arch, mode).run(std::slice::from_ref(layer), wbits, abits);
                let expect = rep.compute_cycles.max(rep.dma_cycles);
                if (rep.cycles - expect).abs() > 1e-9 * expect.max(1.0) {
                    return Err(format!(
                        "{arch:?}/{mode:?}: cycles {} != max(compute {}, dma {})",
                        rep.cycles, rep.compute_cycles, rep.dma_cycles
                    ));
                }
                if rep.utilization > 1.0 + 1e-12 {
                    return Err(format!("utilization {} > 1", rep.utilization));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fpga_latency_and_energy_monotone_in_bits() {
    // Raising any single channel's bit-width never makes the model faster
    // or cheaper on either architecture.
    forall_ns(
        102,
        |r| {
            let (layer, wbits, abits) = gen_layer(r);
            let bump_w = r.below(2) == 0;
            let idx = if bump_w { r.below(wbits.len()) } else { r.below(abits.len()) };
            (layer, wbits, abits, bump_w, idx)
        },
        |(layer, wbits, abits, bump_w, idx)| {
            let mut wb2 = wbits.clone();
            let mut ab2 = abits.clone();
            if *bump_w {
                wb2[*idx] = (wb2[*idx] + 1).min(32);
            } else {
                ab2[*idx] = (ab2[*idx] + 1).min(32);
            }
            for arch in [Arch::Temporal, Arch::Spatial] {
                let sim = FpgaSim::new(arch, Mode::Quant);
                let base = sim.run(std::slice::from_ref(layer), wbits, abits);
                let more = sim.run(std::slice::from_ref(layer), &wb2, &ab2);
                if more.secs < base.secs - 1e-15 {
                    return Err(format!(
                        "{arch:?}: latency dropped with more bits ({} -> {})",
                        base.secs, more.secs
                    ));
                }
                if more.energy_j < base.energy_j - 1e-15 {
                    return Err(format!(
                        "{arch:?}: energy dropped with more bits ({} -> {})",
                        base.energy_j, more.energy_j
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_saved_config_json_and_packed_form_agree() {
    // A searched config must survive both persistence forms losslessly:
    // the human-readable JSON written by `search --out`, and the §3.4
    // 6-bit packed deployment records — and the two must agree.
    forall(
        103,
        |r| {
            let wbits = gen_bits_vec(r, 48, 32);
            let abits = gen_bits_vec(r, 48, 32);
            (wbits, abits)
        },
        |(wbits, abits)| {
            let out = EpisodeOutcome {
                wbits: wbits.clone(),
                abits: abits.clone(),
                accuracy: 0.875,
                loss: 0.25,
                cost: model_cost(&[], &[], &[]),
                reward: 0.5,
                score: 12.5,
                per_layer: vec![LayerBits { name: "l01_conv".into(), avg_w: 4.0, avg_a: 3.0 }],
                avg_wbits: 4.0,
                avg_abits: 3.0,
            };
            let path = std::env::temp_dir()
                .join(format!("autoq_prop_cfg_{}.json", std::process::id()));
            save_config(&path, "cif10", Mode::Quant, &out).map_err(|e| e.to_string())?;
            let back = load_config(&path).map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();

            if &back.wbits != wbits || &back.abits != abits {
                return Err(format!("JSON roundtrip mutated bits: {:?}", back.wbits));
            }
            // §3.4 packed form agrees with the JSON form.
            let packed_w = pack6(&back.wbits);
            let packed_a = pack6(&back.abits);
            if unpack6(&packed_w, wbits.len()) != *wbits {
                return Err("packed wbits disagree with JSON wbits".into());
            }
            if unpack6(&packed_a, abits.len()) != *abits {
                return Err("packed abits disagree with JSON abits".into());
            }
            Ok(())
        },
        |(w, a)| {
            let mut out = Vec::new();
            for ws in shrink_vec(w) {
                if !ws.is_empty() {
                    out.push((ws, a.clone()));
                }
            }
            for as_ in shrink_vec(a) {
                if !as_.is_empty() {
                    out.push((w.clone(), as_));
                }
            }
            out
        },
    );
}

/// Random matmul shape straddling the kernel tile sizes: mostly small
/// (edge tiles narrower than one block), with dimensions beyond one and
/// two blocks mixed in so every pack/dispatch path runs.
fn gen_matmul_case(r: &mut Rng) -> (usize, usize, usize, Vec<f32>, Vec<f32>, Vec<f32>) {
    let dim = |r: &mut Rng, block: usize| match r.below(4) {
        0 => 1 + r.below(7),             // far below one tile
        1 => block - 2 + r.below(5),     // straddling the tile edge
        2 => block + 1 + r.below(block), // between one and two tiles
        _ => 2 * block + 1 + r.below(9), // beyond two tiles
    };
    let m = 1 + r.below(16); // small m keeps the per-case flop budget down
    let k = dim(r, kernels::KC);
    let n = dim(r, kernels::NC); // arm 3 reaches 3+ column panels (> 2·NC)
    let mut a = vec![0.0f32; m * k];
    let mut b = vec![0.0f32; k * n];
    let mut c0 = vec![0.0f32; m * n];
    r.fill_normal_f32(&mut a, 1.0);
    r.fill_normal_f32(&mut b, 1.0);
    r.fill_normal_f32(&mut c0, 0.5); // nonzero accumulator exercises +=
    (m, k, n, a, b, c0)
}

#[test]
fn prop_blocked_matmul_bit_exact_vs_naive() {
    // The packed, cache-blocked kernels must agree with the naive triple
    // loop to the last bit on every shape — including edge tiles smaller
    // than one block — or parallel/serial byte-identity collapses.
    forall_ns(105, gen_matmul_case, |(m, k, n, a, b, c0)| {
        let (m, k, n) = (*m, *k, *n);
        let mut c_blocked = c0.clone();
        let mut c_naive = c0.clone();
        kernels::matmul_acc(&mut c_blocked, a, b, m, k, n);
        kernels::naive::matmul_acc(&mut c_naive, a, b, m, k, n);
        for (i, (x, y)) in c_blocked.iter().zip(&c_naive).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("matmul_acc ({m},{k},{n}) elem {i}: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_blocked_transpose_matmuls_bit_exact_vs_naive() {
    // Same contract for the training-path contractions: aᵀ@b accumulation
    // and a@bᵀ (shape roles reinterpreted from the generated case).
    forall_ns(106, gen_matmul_case, |(m, k, n, a, b, c0)| {
        let (m, k, n) = (*m, *k, *n);
        // aᵀ @ b: a is (k, m) here, b is (k, n), c (m, n).
        let mut c_blocked = c0.clone();
        let mut c_naive = c0.clone();
        kernels::matmul_at_b_acc(&mut c_blocked, a, b, k, m, n);
        kernels::naive::matmul_at_b_acc(&mut c_naive, a, b, k, m, n);
        for (i, (x, y)) in c_blocked.iter().zip(&c_naive).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("at_b_acc ({k},{m},{n}) elem {i}: {x} vs {y}"));
            }
        }
        // a @ bᵀ: a is (m, k), b is (n, k) — reuse b by reading it as rows.
        let bt = &b[..n * k];
        let c_blocked = kernels::matmul_a_bt(a, bt, m, k, n);
        let c_naive = kernels::naive::matmul_a_bt(a, bt, m, k, n);
        for (i, (x, y)) in c_blocked.iter().zip(&c_naive).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("a_bt ({m},{k},{n}) elem {i}: {x} vs {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_generated_bits_are_valid_config_entries() {
    // gen_bits_vec feeds config-level properties: every entry must already
    // be a valid searched bit-width (1..=32) so `load_config` validation
    // never rejects generated cases.
    forall_ns(104, |r| gen_bits_vec(r, 64, 32), |bits| {
        if bits.is_empty() {
            return Err("empty bit vector".into());
        }
        if let Some(&bad) = bits.iter().find(|&&b| !(1..=32).contains(&b)) {
            return Err(format!("generated invalid bit-width {bad}"));
        }
        Ok(())
    });
}
