//! Property tests over the deterministic substrate (`util::prop::forall`):
//! FPGA-simulator conservation laws, latency/energy monotonicity in the
//! bit-widths, and bit-config persistence round-trips (JSON `SavedConfig`
//! vs the §3.4 6-bit packed form).

use autoq::cost::logic::model_cost;
use autoq::cost::Mode;
use autoq::models::storage::{pack6, unpack6};
use autoq::quant::{load_config, save_config};
use autoq::runtime::LayerMeta;
use autoq::search::{EpisodeOutcome, LayerBits};
use autoq::sim::{Arch, FpgaSim};
use autoq::util::prop::{forall, forall_ns, gen_bits_vec, shrink_vec};
use autoq::util::rng::Rng;

/// Random but self-consistent conv/dwconv/fc layer + per-channel bits.
fn gen_layer(r: &mut Rng) -> (LayerMeta, Vec<u8>, Vec<u8>) {
    let typ = match r.below(4) {
        0 => "fc",
        1 => "dwconv",
        _ => "conv",
    };
    let (k, s) = if typ == "fc" { (1, 1) } else { ([1usize, 3][r.below(2)], 1 + r.below(2)) };
    let cin = 1 + r.below(8);
    let cout = if typ == "dwconv" { cin } else { 1 + r.below(8) };
    let (h_in, w_in) = if typ == "fc" { (1, 1) } else { (4 + r.below(13), 4 + r.below(13)) };
    let h_out = (h_in + s - 1) / s;
    let w_out = (w_in + s - 1) / s;
    let macs = match typ {
        "fc" => (cin * cout) as u64,
        "dwconv" => (h_out * w_out * k * k * cin) as u64,
        _ => (h_out * w_out * k * k * cin * cout) as u64,
    };
    let a_len = if typ == "fc" { 1 } else { cin };
    let layer = LayerMeta {
        name: "lp_test".into(),
        typ: typ.into(),
        k,
        stride: s,
        cin,
        cout,
        h_in,
        w_in,
        h_out,
        w_out,
        macs,
        w_off: 0,
        w_len: cout,
        a_off: 0,
        a_len,
    };
    // Mostly live channels (≥1 bit via gen_bits_vec semantics), with
    // deliberate pruning sprinkled in to exercise the 0-bit path.
    let mut wbits: Vec<u8> = (0..cout).map(|_| 1 + r.below(8) as u8).collect();
    let mut abits: Vec<u8> = (0..a_len).map(|_| 1 + r.below(8) as u8).collect();
    if r.below(4) == 0 {
        wbits[r.below(cout)] = 0;
    }
    if r.below(8) == 0 {
        abits[r.below(a_len)] = 0;
    }
    (layer, wbits, abits)
}

#[test]
fn prop_fpga_layer_time_is_max_of_compute_and_dma() {
    // Double-buffered DMA: a single-layer model's total time must be
    // exactly max(compute, dma) — neither sum nor min.
    forall_ns(101, gen_layer, |(layer, wbits, abits)| {
        for arch in [Arch::Temporal, Arch::Spatial] {
            for mode in [Mode::Quant, Mode::Binar] {
                let rep = FpgaSim::new(arch, mode).run(std::slice::from_ref(layer), wbits, abits);
                let expect = rep.compute_cycles.max(rep.dma_cycles);
                if (rep.cycles - expect).abs() > 1e-9 * expect.max(1.0) {
                    return Err(format!(
                        "{arch:?}/{mode:?}: cycles {} != max(compute {}, dma {})",
                        rep.cycles, rep.compute_cycles, rep.dma_cycles
                    ));
                }
                if rep.utilization > 1.0 + 1e-12 {
                    return Err(format!("utilization {} > 1", rep.utilization));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fpga_latency_and_energy_monotone_in_bits() {
    // Raising any single channel's bit-width never makes the model faster
    // or cheaper on either architecture.
    forall_ns(
        102,
        |r| {
            let (layer, wbits, abits) = gen_layer(r);
            let bump_w = r.below(2) == 0;
            let idx = if bump_w { r.below(wbits.len()) } else { r.below(abits.len()) };
            (layer, wbits, abits, bump_w, idx)
        },
        |(layer, wbits, abits, bump_w, idx)| {
            let mut wb2 = wbits.clone();
            let mut ab2 = abits.clone();
            if *bump_w {
                wb2[*idx] = (wb2[*idx] + 1).min(32);
            } else {
                ab2[*idx] = (ab2[*idx] + 1).min(32);
            }
            for arch in [Arch::Temporal, Arch::Spatial] {
                let sim = FpgaSim::new(arch, Mode::Quant);
                let base = sim.run(std::slice::from_ref(layer), wbits, abits);
                let more = sim.run(std::slice::from_ref(layer), &wb2, &ab2);
                if more.secs < base.secs - 1e-15 {
                    return Err(format!(
                        "{arch:?}: latency dropped with more bits ({} -> {})",
                        base.secs, more.secs
                    ));
                }
                if more.energy_j < base.energy_j - 1e-15 {
                    return Err(format!(
                        "{arch:?}: energy dropped with more bits ({} -> {})",
                        base.energy_j, more.energy_j
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_saved_config_json_and_packed_form_agree() {
    // A searched config must survive both persistence forms losslessly:
    // the human-readable JSON written by `search --out`, and the §3.4
    // 6-bit packed deployment records — and the two must agree.
    forall(
        103,
        |r| {
            let wbits = gen_bits_vec(r, 48, 32);
            let abits = gen_bits_vec(r, 48, 32);
            (wbits, abits)
        },
        |(wbits, abits)| {
            let out = EpisodeOutcome {
                wbits: wbits.clone(),
                abits: abits.clone(),
                accuracy: 0.875,
                loss: 0.25,
                cost: model_cost(&[], &[], &[]),
                reward: 0.5,
                score: 12.5,
                per_layer: vec![LayerBits { name: "l01_conv".into(), avg_w: 4.0, avg_a: 3.0 }],
                avg_wbits: 4.0,
                avg_abits: 3.0,
            };
            let path = std::env::temp_dir()
                .join(format!("autoq_prop_cfg_{}.json", std::process::id()));
            save_config(&path, "cif10", Mode::Quant, &out).map_err(|e| e.to_string())?;
            let back = load_config(&path).map_err(|e| e.to_string())?;
            std::fs::remove_file(&path).ok();

            if &back.wbits != wbits || &back.abits != abits {
                return Err(format!("JSON roundtrip mutated bits: {:?}", back.wbits));
            }
            // §3.4 packed form agrees with the JSON form.
            let packed_w = pack6(&back.wbits);
            let packed_a = pack6(&back.abits);
            if unpack6(&packed_w, wbits.len()) != *wbits {
                return Err("packed wbits disagree with JSON wbits".into());
            }
            if unpack6(&packed_a, abits.len()) != *abits {
                return Err("packed abits disagree with JSON abits".into());
            }
            Ok(())
        },
        |(w, a)| {
            let mut out = Vec::new();
            for ws in shrink_vec(w) {
                if !ws.is_empty() {
                    out.push((ws, a.clone()));
                }
            }
            for as_ in shrink_vec(a) {
                if !as_.is_empty() {
                    out.push((w.clone(), as_));
                }
            }
            out
        },
    );
}

#[test]
fn prop_generated_bits_are_valid_config_entries() {
    // gen_bits_vec feeds config-level properties: every entry must already
    // be a valid searched bit-width (1..=32) so `load_config` validation
    // never rejects generated cases.
    forall_ns(104, |r| gen_bits_vec(r, 64, 32), |bits| {
        if bits.is_empty() {
            return Err("empty bit vector".into());
        }
        if let Some(&bad) = bits.iter().find(|&&b| !(1..=32).contains(&b)) {
            return Err(format!("generated invalid bit-width {bad}"));
        }
        Ok(())
    });
}
