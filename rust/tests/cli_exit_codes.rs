//! The CLI exit-code contract (documented in `autoq help`):
//!   0 — success, including `--help`
//!   1 — job or runtime failure (structured errors: rejected spec,
//!       missing model, failed daemon job)
//!   2 — caller mistakes (unknown command/option, malformed values)
//!
//! These are subprocess tests: the contract lives in `main()`'s error
//! triage, which unit tests cannot reach.

use std::process::{Command, Output};

fn autoq(args: &[&str]) -> Output {
    let dir = std::env::temp_dir().join(format!("autoq_exit_{}", std::process::id()));
    Command::new(env!("CARGO_BIN_EXE_autoq"))
        .args(args)
        .env("AUTOQ_ARTIFACTS", &dir)
        .output()
        .expect("spawn autoq")
}

fn code(out: &Output) -> i32 {
    out.status.code().expect("no exit code (signal?)")
}

#[test]
fn help_exits_zero() {
    let out = autoq(&["help"]);
    assert_eq!(code(&out), 0);
    assert!(String::from_utf8_lossy(&out.stdout).contains("exit codes"));
    // Subcommand --help is also help, not an error.
    let out = autoq(&["search", "--help"]);
    assert_eq!(code(&out), 0, "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("--episodes"));
}

#[test]
fn unknown_command_and_option_exit_two() {
    let out = autoq(&["frobnicate"]);
    assert_eq!(code(&out), 2);
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));

    let out = autoq(&["search", "--nope", "1"]);
    assert_eq!(code(&out), 2);
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown option"));

    let out = autoq(&["search", "--episodes", "abc"]);
    assert_eq!(code(&out), 2);
    assert!(String::from_utf8_lossy(&out.stderr).contains("expects an integer"));

    let out = autoq(&["submit", "--kind", "nope"]);
    assert_eq!(code(&out), 2);
}

/// Structured job errors (the PR 5 episodes==0 case) are failures, not
/// usage mistakes — and decidedly not success.
#[test]
fn rejected_specs_and_missing_models_exit_one() {
    let out = autoq(&["search", "--episodes", "0"]);
    assert_eq!(code(&out), 1, "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stderr).contains("episodes"));

    let out = autoq(&["eval", "--model", "no_such_model"]);
    assert_eq!(code(&out), 1);

    // A dead daemon address is a runtime failure too.
    let out = autoq(&["status", "--addr", "127.0.0.1:1"]);
    assert_eq!(code(&out), 1);
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot reach"));
}
