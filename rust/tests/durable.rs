//! Crash-safety tests for the durable job journal (DESIGN.md §Durable
//! jobs): SIGKILL a sweep and a search mid-run and prove `--resume` /
//! checkpoint resume reproduce the uninterrupted run byte-for-byte while
//! re-running only the unfinished units; recover torn journal tails; and
//! restart an `autoq serve` daemon into its journaled jobs + disk-tier
//! eval cache.

use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use autoq::coordinator::{Coordinator, JobSpec, Sweep};
use autoq::cost::Mode;
use autoq::runtime::{BackendKind, Parallelism};
use autoq::search::{Granularity, Protocol};
use autoq::serve::{DaemonClient, ServeConfig, Server};
use autoq::util::json::Json;

fn exe() -> PathBuf {
    static EXE: OnceLock<PathBuf> = OnceLock::new();
    EXE.get_or_init(|| PathBuf::from(env!("CARGO_BIN_EXE_autoq"))).clone()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("autoq_durable_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Persist cheap (3-step) trained params so every run below loads
/// identical bytes instead of auto-pretraining 300 steps mid-test.
fn seed_params(dir: &Path) {
    let mut coord = Coordinator::open_with(dir, Some(BackendKind::Reference)).unwrap();
    coord.run(&JobSpec::pretrain("cif10").steps(3).build().unwrap()).unwrap();
}

/// Report files in `dir` as sorted (name, secs-zeroed JSON) rows — the
/// journal file itself is not a report and is skipped.
fn canon(dir: &Path) -> Vec<(String, String)> {
    let mut rows: Vec<(String, String)> = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| {
            let path = e.unwrap().path();
            if path.extension().and_then(|s| s.to_str()) != Some("json") {
                return None;
            }
            let mut j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            if let Json::Obj(m) = &mut j {
                m.insert("secs".to_string(), Json::Num(0.0));
            }
            Some((path.file_name().unwrap().to_string_lossy().into_owned(), j.to_string()))
        })
        .collect();
    rows.sort();
    rows
}

fn zero_secs(j: &Json) -> String {
    let mut j = j.clone();
    if let Json::Obj(m) = &mut j {
        m.insert("secs".to_string(), Json::Num(0.0));
    }
    j.to_string()
}

/// Poll until `path` exists with at least `min_len` bytes (or panic at the
/// deadline).  Returns false if the watched child exited first.
fn wait_for_file(
    path: &Path,
    min_len: u64,
    child: &mut std::process::Child,
    deadline: Duration,
) -> bool {
    let t0 = Instant::now();
    loop {
        if let Ok(md) = std::fs::metadata(path) {
            if md.len() >= min_len {
                return true;
            }
        }
        if child.try_wait().unwrap().is_some() {
            return false; // finished before we could interrupt it
        }
        assert!(
            t0.elapsed() < deadline,
            "timed out waiting for {} to reach {min_len} bytes",
            path.display()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Parse `"<n> <marker>"` out of a CLI summary line.
fn count_before(stdout: &str, marker: &str) -> usize {
    let line = stdout
        .lines()
        .find(|l| l.contains(marker))
        .unwrap_or_else(|| panic!("no {marker:?} line in output:\n{stdout}"));
    line.split(marker).next().unwrap().split_whitespace().last().unwrap().parse().unwrap()
}

/// SIGKILL `autoq sweep` after its first cell lands, then `--resume`: the
/// per-cell report JSONs must be byte-identical (modulo `secs`) to an
/// uninterrupted run, with only the unfinished cells re-run.
#[cfg(unix)]
#[test]
fn sweep_survives_sigkill_and_resumes_byte_identical() {
    let exe = exe();
    let dir = temp_dir("sweep_kill");
    seed_params(&dir);
    let run = |out: &Path, extra: &[&str]| {
        let mut cmd = Command::new(&exe);
        cmd.args([
            "sweep",
            "--models",
            "cif10",
            "--modes",
            "quant",
            "--protocols",
            "rc,ag",
            "--granularities",
            "network:4",
            "--episodes",
            "4",
            "--warmup",
            "1",
            "--eval-batches",
            "2",
            "--seed",
            "21",
            "--workers",
            "1",
            "--threads",
            "2",
            "--backend",
            "reference",
            "--out-dir",
        ])
        .arg(out)
        .args(extra)
        .env("AUTOQ_ARTIFACTS", &dir)
        .stderr(Stdio::null());
        cmd
    };

    // Uninterrupted baseline.
    let base = dir.join("base");
    let st = run(&base, &[]).stdout(Stdio::null()).status().unwrap();
    assert!(st.success());
    let want = canon(&base);
    assert_eq!(want.len(), 2, "grid must expand to two cells");

    // Killed run: one worker runs the two cells serially; SIGKILL as soon
    // as the journal holds the first cell's DONE record.
    let res = dir.join("res");
    let mut child = run(&res, &[]).stdout(Stdio::null()).spawn().unwrap();
    let interrupted =
        wait_for_file(&res.join("sweep.journal"), 512, &mut child, Duration::from_secs(120));
    if interrupted {
        child.kill().unwrap(); // SIGKILL — no drop handlers, no flush
    }
    child.wait().unwrap();

    // Resume: finished cells skip, the rest re-run, bytes converge.
    let out = run(&res, &["--resume"]).output().unwrap();
    assert!(out.status.success(), "resume failed: {}", String::from_utf8_lossy(&out.stdout));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    let skipped = count_before(&stdout, " skipped (journaled)");
    let completed = count_before(&stdout, " job(s) completed");
    assert!(skipped >= 1, "at least the first cell must be journaled:\n{stdout}");
    assert_eq!(completed + skipped, 2, "every cell must be accounted for:\n{stdout}");
    assert_eq!(canon(&res), want, "resumed sweep diverged from the uninterrupted run");
    std::fs::remove_dir_all(&dir).ok();
}

/// SIGKILL `autoq search --checkpoint-every 1` mid-run, re-run the same
/// command, and require the final searched config to be byte-identical to
/// an uninterrupted (checkpoint-free) run's.
#[cfg(unix)]
#[test]
fn search_survives_sigkill_and_resumes_byte_identical() {
    let exe = exe();
    let dir = temp_dir("search_kill");
    seed_params(&dir);
    let run = |out: &Path, every: &str| {
        let mut cmd = Command::new(&exe);
        cmd.args([
            "search",
            "--model",
            "cif10",
            "--mode",
            "quant",
            "--protocol",
            "rc",
            "--target-bits",
            "5",
            "--granularity",
            "network:4",
            "--episodes",
            "4",
            "--warmup",
            "1",
            "--eval-batches",
            "1",
            "--seed",
            "3",
            "--threads",
            "2",
            "--backend",
            "reference",
            "--checkpoint-every",
            every,
            "--out",
        ])
        .arg(out)
        .env("AUTOQ_ARTIFACTS", &dir)
        .stdout(Stdio::null())
        .stderr(Stdio::null());
        cmd
    };
    // The checkpoint journal lives under the artifact dir, named by the
    // job id the CLI flags above resolve to.
    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Network(4))
        .episodes(4)
        .warmup(1)
        .eval_batches(1)
        .seed(3)
        .build()
        .unwrap();
    let journal = dir.join("checkpoints").join(format!("{}.journal", spec.id()));

    // Uninterrupted, checkpoint-free baseline.
    let base = dir.join("base.json");
    assert!(run(&base, "0").status().unwrap().success());

    // Killed run: SIGKILL once the first per-episode snapshot is on disk.
    let out = dir.join("res.json");
    let mut child = run(&out, "1").spawn().unwrap();
    let interrupted = wait_for_file(&journal, 64, &mut child, Duration::from_secs(120));
    if interrupted {
        child.kill().unwrap();
    }
    child.wait().unwrap();

    // Same command again: resumes from the snapshot (or restarts clean if
    // the kill landed before one) and must converge on the same bytes.
    assert!(run(&out, "1").status().unwrap().success());
    assert_eq!(
        std::fs::read(&base).unwrap(),
        std::fs::read(&out).unwrap(),
        "resumed search config diverged from the uninterrupted run"
    );
    assert!(!journal.exists(), "a finished search must remove its checkpoint journal");
    std::fs::remove_dir_all(&dir).ok();
}

/// In-process resume semantics: a completed sweep's journal skips every
/// cell (re-materializing deleted report files byte-exactly), and a torn
/// journal tail loses exactly its own record — the resume re-runs that one
/// cell and converges on identical bytes.
#[test]
fn sweep_resume_skips_done_cells_and_recovers_torn_journals() {
    let dir = temp_dir("resume_torn");
    seed_params(&dir);
    let out_dir = dir.join("out");
    let grid = Sweep {
        protocols: vec![Protocol::resource_constrained(5.0), Protocol::accuracy_guaranteed()],
        granularities: vec![Granularity::Network(4)],
        episodes: 3,
        warmup: 1,
        eval_batches: 1,
        base_seed: 9,
        workers: 1,
        out_dir: Some(out_dir.clone()),
        backend: Some(BackendKind::Reference),
        threads: Some(Parallelism::new(2)),
        ..Sweep::default()
    };
    let r1 = grid.run(&dir).unwrap();
    assert!(r1.failures.is_empty(), "{:?}", r1.failures);
    assert_eq!(r1.reports.len(), 2);
    assert!(r1.skipped.is_empty());
    let want = canon(&out_dir);

    // Resume over a complete journal: nothing runs, and a deleted report
    // file comes back byte-exactly from the journal.
    let lost = out_dir.join(format!("{}.json", r1.reports[0].id()));
    std::fs::remove_file(&lost).unwrap();
    let resume = Sweep { resume: true, ..grid.clone() };
    let r2 = resume.run(&dir).unwrap();
    assert_eq!(r2.reports.len(), 0, "a complete journal must skip every cell");
    assert_eq!(r2.skipped.len(), 2);
    assert!(lost.exists(), "skipped cells must re-materialize missing report files");
    assert_eq!(canon(&out_dir), want);

    // Torn tail: chop bytes off the last record; only that cell re-runs.
    let jpath = out_dir.join("sweep.journal");
    let bytes = std::fs::read(&jpath).unwrap();
    std::fs::write(&jpath, &bytes[..bytes.len() - 7]).unwrap();
    let r3 = resume.run(&dir).unwrap();
    assert!(r3.failures.is_empty(), "{:?}", r3.failures);
    assert_eq!(r3.skipped.len(), 1, "the torn record must lose exactly its own cell");
    assert_eq!(r3.reports.len(), 1);
    assert_eq!(canon(&out_dir), want, "re-run after tail truncation diverged");

    // A changed grid under the same out-dir re-runs the changed cell even
    // though its id is journaled (fingerprint mismatch).
    let mut changed = resume.clone();
    changed.episodes = 4;
    let r4 = changed.run(&dir).unwrap();
    assert_eq!(r4.skipped.len(), 0, "changed specs must not reuse stale journal entries");
    assert_eq!(r4.reports.len(), 2);
    std::fs::remove_dir_all(&dir).ok();
}

/// Restarted daemon: journaled jobs answer `result` after the restart, a
/// previously-evaluated search is served entirely from the disk-tier eval
/// cache (hits, zero misses, byte-identical report), and the `status`
/// reply surfaces the durability info.
#[test]
fn restarted_daemon_serves_cached_evals_from_the_disk_tier() {
    let dir = temp_dir("serve_restart");
    seed_params(&dir);
    let start = || {
        let cfg = ServeConfig {
            dir: dir.clone(),
            backend: Some(BackendKind::Reference),
            threads: Some(Parallelism::new(2)),
            workers: 1,
            ..ServeConfig::default()
        };
        let server = Server::bind("127.0.0.1:0", cfg).unwrap();
        let addr = server.local_addr().to_string();
        (addr, std::thread::spawn(move || server.run()))
    };
    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Network(5))
        .episodes(2)
        .warmup(1)
        .eval_batches(1)
        .seed(7)
        .build()
        .unwrap();

    // First daemon lifetime: run the search, then drain-shutdown.
    let (addr, thread) = start();
    let mut client = DaemonClient::connect(&addr).unwrap();
    let handle = client.submit(&spec).unwrap();
    assert_eq!(handle, "job-0");
    let row = client.result(&handle, true).unwrap();
    assert_eq!(row.req("state").unwrap().as_str(), Some("done"));
    let want = zero_secs(row.req("report").unwrap());
    client.shutdown(true).unwrap();
    thread.join().unwrap().unwrap();
    assert!(dir.join("serve").join("jobs.journal").exists());
    assert!(dir.join("serve").join("eval_cache.journal").exists());

    // Second daemon lifetime over the same artifact dir.
    let (addr, thread) = start();
    let mut client = DaemonClient::connect(&addr).unwrap();

    // The pre-restart job was restored from the journal, report intact.
    let row = client.result("job-0", false).unwrap();
    assert_eq!(row.req("state").unwrap().as_str(), Some("done"));
    assert_eq!(zero_secs(row.req("report").unwrap()), want, "restored report diverged");

    // Re-submitting the same spec: every eval answers from the disk tier
    // (the daemon restarted with an empty memory map), zero misses, and
    // the report stays byte-identical.
    let handle = client.submit(&spec).unwrap();
    assert_eq!(handle, "job-1", "restored jobs must keep their handles");
    let row = client.result(&handle, true).unwrap();
    assert_eq!(row.req("state").unwrap().as_str(), Some("done"));
    assert_eq!(zero_secs(row.req("report").unwrap()), want, "disk-tier-served report diverged");
    let cache = row.req("cache").unwrap();
    let hits = cache.req("hits").unwrap().as_usize().unwrap();
    let misses = cache.req("misses").unwrap().as_usize().unwrap();
    assert!(hits > 0, "restarted daemon must serve evals from the disk tier");
    assert_eq!(misses, 0, "a byte-identical repeat must add no misses");

    // Durability info rides the bare status reply.
    let status = client.status(None).unwrap();
    let d = status.req("durability").unwrap();
    assert!(d.req("jobs_journal").unwrap().as_str().unwrap().ends_with("jobs.journal"));
    assert!(d.req("jobs_journaled").unwrap().as_usize().unwrap() >= 1);
    assert!(d.req("disk_cache_entries").unwrap().as_usize().unwrap() > 0);

    client.shutdown(true).unwrap();
    thread.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}
