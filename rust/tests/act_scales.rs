//! Static activation-scale calibration (`--act-scales static`,
//! DESIGN.md §Integer kernels):
//!
//! * **Determinism**: calibrating the same model twice — across separate
//!   coordinator instances — reproduces byte-identical per-layer maxes,
//!   the same fingerprint, and a byte-identical persisted table.
//! * **Agreement**: static-scale evals track dynamic-scale evals within
//!   the quantization error budget, and repeat static evals are
//!   byte-deterministic.
//! * **Cache separation**: the calibration fingerprint is part of the
//!   eval-cache key — a static eval never aliases a dynamic one — while a
//!   cached static eval stays byte-identical to an uncached one.
//!
//! The static-scale registry (`model_exec::set_act_scales`) is process
//! global and keyed by model name, so every test here serializes on one
//! lock and clears the registry before returning.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use autoq::coordinator::{act_table_fingerprint, ActScaleMode, Coordinator, JobSpec};
use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::runtime::reference::model_exec;
use autoq::runtime::BackendKind;
use autoq::serve::cache::CacheHandle;

static LOCK: Mutex<()> = Mutex::new(());

const MODEL: &str = "cif10";

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("autoq_acts_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Persist cheap trained params once so every coordinator in a test loads
/// the same bytes instead of auto-pretraining 300 steps.
fn seed_params(dir: &Path) {
    let mut coord = Coordinator::open_with(dir, Some(BackendKind::Reference)).unwrap();
    coord.run(&JobSpec::pretrain(MODEL).steps(3).build().unwrap()).unwrap();
}

fn open_static(dir: &Path) -> Coordinator {
    let mut coord = Coordinator::open_with(dir, Some(BackendKind::Reference)).unwrap();
    coord.set_act_scale_mode(ActScaleMode::Static);
    coord
}

#[test]
fn act_scale_mode_parses_and_defaults() {
    assert_eq!(ActScaleMode::parse("static").unwrap(), ActScaleMode::Static);
    assert_eq!(ActScaleMode::parse("dynamic").unwrap(), ActScaleMode::Dynamic);
    assert!(ActScaleMode::parse("auto").is_err());
    assert_eq!(ActScaleMode::Static.as_str(), "static");
    assert_eq!(ActScaleMode::Dynamic.as_str(), "dynamic");
    // A fresh coordinator defaults to dynamic ($AUTOQ_ACT_SCALES unset in
    // the test environment); the setter overrides it.
    let dir = temp_dir("mode");
    let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
    assert_eq!(coord.act_scale_mode(), ActScaleMode::Dynamic);
    coord.set_act_scale_mode(ActScaleMode::Static);
    assert_eq!(coord.act_scale_mode(), ActScaleMode::Static);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn calibration_is_deterministic_across_loads() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = temp_dir("determ");
    seed_params(&dir);

    let mut c1 = open_static(&dir);
    c1.ensure_pretrained(MODEL).unwrap();
    let t1 = model_exec::act_scales_for(MODEL).expect("static mode must install a table");
    let f1 = std::fs::read(c1.act_scales_path(MODEL)).expect("table must persist");
    assert_ne!(t1.fingerprint, 0, "0 is the reserved dynamic fingerprint");
    assert_eq!(t1.fingerprint, act_table_fingerprint(MODEL, &t1.maxes));
    assert!(t1.maxes.iter().all(|m| m.is_finite() && *m >= 0.0), "{:?}", t1.maxes);
    assert!(t1.maxes.iter().any(|&m| m > 0.0), "calibration saw real activations");
    drop(c1);
    model_exec::set_act_scales(MODEL, None);

    let mut c2 = open_static(&dir);
    c2.ensure_pretrained(MODEL).unwrap();
    let t2 = model_exec::act_scales_for(MODEL).expect("recalibrated");
    let f2 = std::fs::read(c2.act_scales_path(MODEL)).unwrap();
    assert_eq!(t1.maxes.len(), t2.maxes.len());
    for (i, (a, b)) in t1.maxes.iter().zip(&t2.maxes).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "layer {i} max drifted between calibrations");
    }
    assert_eq!(t1.fingerprint, t2.fingerprint);
    assert_eq!(f1, f2, "persisted calibration tables must be byte-identical");

    model_exec::set_act_scales(MODEL, None);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn static_and_dynamic_evals_agree_within_tolerance() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = temp_dir("agree");
    seed_params(&dir);

    let mut coord = open_static(&dir);
    let runner = coord.fresh_runner(MODEL).unwrap();
    assert_ne!(runner.calib_fingerprint(), 0, "static runner must carry its calibration fp");
    let data = SynthDataset::new(42);
    let wbits = vec![5u8; runner.meta.w_channels];
    let abits = vec![4u8; runner.meta.a_channels];
    let rt = coord.runtime();
    let mut eval = |rt: &mut autoq::runtime::Runtime| {
        runner.eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1).unwrap()
    };
    let st1 = eval(&mut *rt);
    let st2 = eval(&mut *rt);
    assert_eq!(st1.accuracy.to_bits(), st2.accuracy.to_bits(), "static evals must repeat exactly");
    assert_eq!(st1.loss.to_bits(), st2.loss.to_bits());

    // Same runner with the table cleared falls back to dynamic scales.
    model_exec::set_act_scales(MODEL, None);
    let dy = eval(&mut *rt);
    assert_eq!(st1.images, dy.images);
    assert!(
        (st1.accuracy - dy.accuracy).abs() <= 0.1,
        "static accuracy {} vs dynamic {}",
        st1.accuracy,
        dy.accuracy
    );
    assert!(
        (st1.loss - dy.loss).abs() <= 0.1 * (1.0 + dy.loss.abs()),
        "static loss {} vs dynamic {}",
        st1.loss,
        dy.loss
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn static_eval_memoizes_and_never_aliases_dynamic() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = temp_dir("alias");
    seed_params(&dir);

    let mut coord = open_static(&dir);
    let mut runner = coord.fresh_runner(MODEL).unwrap();
    let plain = coord.fresh_runner(MODEL).unwrap();
    let handle = CacheHandle::private();
    runner.set_eval_cache(Some(handle.clone()));
    let data = SynthDataset::new(42);
    let wbits = vec![5u8; runner.meta.w_channels];
    let abits = vec![4u8; runner.meta.a_channels];
    let rt = coord.runtime();

    let cold = runner.eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1).unwrap();
    assert_eq!(handle.counts(), (0, 1), "first static eval must miss");
    let warm = runner.eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1).unwrap();
    assert_eq!(handle.counts(), (1, 1), "identical static eval must hit");
    assert_eq!(warm.accuracy.to_bits(), cold.accuracy.to_bits());
    assert_eq!(warm.loss.to_bits(), cold.loss.to_bits());

    // A cache hit returns exactly what an uncached static runner computes.
    let bare = plain.eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1).unwrap();
    assert_eq!(bare.accuracy.to_bits(), warm.accuracy.to_bits());
    assert_eq!(bare.loss.to_bits(), warm.loss.to_bits());

    // Flip the same runner to dynamic (fingerprint 0, no table): the
    // stored static entry must NOT be served for the dynamic eval.
    runner.set_calib_fingerprint(0);
    model_exec::set_act_scales(MODEL, None);
    runner.eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1).unwrap();
    assert_eq!(handle.counts(), (1, 2), "dynamic eval must miss the static entry");

    std::fs::remove_dir_all(&dir).ok();
}
