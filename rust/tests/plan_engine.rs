//! Planned-execution engine contracts:
//!
//! * **Byte-identity**: the two-phase plan/workspace engine reproduces the
//!   PR 3 allocate-per-call tree-walk bit-for-bit — eval for every zoo
//!   model × quant/binar × 1/2/4 worker threads, train for every model ×
//!   mode.
//! * **Workspace reuse**: after one warm-up `eval_config`, further calls
//!   grow neither the workspace count nor the resident buffer footprint —
//!   steady-state batches allocate no new scratch.

use std::sync::Arc;

use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::reference::model_exec::{RefModelEval, RefModelTrain};
use autoq::runtime::reference::zoo::{model_graph, IMAGE_HW, MODEL_NAMES};
use autoq::runtime::{BackendKind, Parallelism, Runtime, Tensor, Value};
use autoq::util::pool::WorkerPool;
use autoq::util::rng::Rng;

fn images(n: usize, seed: u64) -> Value {
    let mut rng = Rng::new(seed);
    let mut data = vec![0.0f32; n * IMAGE_HW * IMAGE_HW * 3];
    rng.fill_normal_f32(&mut data, 0.5);
    Value::F32(Tensor::new(vec![n, IMAGE_HW, IMAGE_HW, 3], data))
}

fn labels(n: usize, shift: i32) -> Value {
    Value::i32(vec![n], (0..n as i32).map(|i| (i + shift) % 10).collect())
}

/// Mixed bit vector: live low-bit channels with pruned and passthrough
/// channels sprinkled in, so every quantizer path (0-bit, low-bit, ≥24
/// passthrough) runs under the plan engine.
fn mixed_bits(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..len)
        .map(|_| match rng.below(8) {
            0 => 0.0,
            1 => 32.0,
            b => b as f32,
        })
        .collect()
}

#[test]
fn planned_eval_matches_walk_for_all_models_modes_threads() {
    for name in MODEL_NAMES {
        let g = model_graph(name).unwrap();
        let ps = ParamStore::init(&g.params, &mut Rng::new(7));
        let base: Vec<Value> = ps.tensors.iter().map(|t| Value::F32(t.clone())).collect();
        let wbits = Value::f32(vec![g.w_channels], mixed_bits(g.w_channels, 11));
        let abits = Value::f32(vec![g.a_channels], mixed_bits(g.a_channels, 13));
        let n = 4;
        let batches_owned: Vec<(Value, Value)> =
            (0..3u64).map(|bi| (images(n, 100 + bi), labels(n, bi as i32))).collect();
        let batches: Vec<Vec<&Value>> = batches_owned
            .iter()
            .map(|(img, lbl)| {
                let mut row: Vec<&Value> = base.iter().collect();
                row.push(img);
                row.push(lbl);
                row.push(&wbits);
                row.push(&abits);
                row
            })
            .collect();
        for binar in [false, true] {
            // The retained tree-walk is the semantic reference.
            let walker = RefModelEval::new(g.clone(), binar, Arc::new(WorkerPool::new(1)));
            let expect: Vec<Vec<Value>> =
                batches.iter().map(|b| walker.run_walk(b).unwrap()).collect();
            for threads in [1usize, 2, 4] {
                let mut exe =
                    RefModelEval::new(g.clone(), binar, Arc::new(WorkerPool::new(threads)));
                // Twice: cold workspaces, then warm reuse.
                for round in 0..2 {
                    let outs = autoq::runtime::Executable::execute_batch(&mut exe, &batches)
                        .unwrap();
                    assert_eq!(outs.len(), expect.len());
                    for (bi, (o, e)) in outs.iter().zip(&expect).enumerate() {
                        for k in 0..2 {
                            assert_eq!(
                                o[k].scalar_f32().unwrap().to_bits(),
                                e[k].scalar_f32().unwrap().to_bits(),
                                "{name} binar={binar} threads={threads} round={round} \
                                 batch={bi} out={k}"
                            );
                        }
                    }
                }
                let stats = autoq::runtime::Executable::scratch_stats(&exe).unwrap();
                assert!(stats.workspaces <= threads.min(batches.len()), "{name}");
            }
        }
    }
}

#[test]
fn planned_train_matches_walk_for_all_models_modes() {
    for name in MODEL_NAMES {
        let g = model_graph(name).unwrap();
        let ps = ParamStore::init(&g.params, &mut Rng::new(19));
        let momenta = ps.zeros_like();
        let n = 2;
        let np = g.params.len();
        let mut inputs: Vec<Value> = Vec::with_capacity(2 * np + 5);
        inputs.extend(ps.tensors.iter().map(|t| Value::F32(t.clone())));
        inputs.extend(momenta.tensors.iter().map(|t| Value::F32(t.clone())));
        inputs.push(images(n, 23));
        inputs.push(labels(n, 1));
        inputs.push(Value::f32(vec![g.w_channels], mixed_bits(g.w_channels, 29)));
        inputs.push(Value::f32(vec![g.a_channels], mixed_bits(g.a_channels, 31)));
        inputs.push(Value::scalar(0.05));
        let refs: Vec<&Value> = inputs.iter().collect();
        for binar in [false, true] {
            let mut exe = RefModelTrain::new(g.clone(), binar);
            let walk = exe.run_walk(&refs).unwrap();
            // Twice: cold plan + workspace, then warm reuse.
            for round in 0..2 {
                let planned = autoq::runtime::Executable::execute(&mut exe, &refs).unwrap();
                assert_eq!(planned.len(), walk.len(), "{name}");
                for (i, (p, w)) in planned.iter().zip(&walk).enumerate() {
                    let (pt, wt) = (p.as_f32().unwrap(), w.as_f32().unwrap());
                    assert_eq!(pt.shape, wt.shape, "{name} out {i}");
                    for (j, (a, b)) in pt.data.iter().zip(&wt.data).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{name} binar={binar} round={round} out {i} elem {j}"
                        );
                    }
                }
            }
        }
    }
}

/// Steady-state `eval_config` allocates no new scratch: the executable's
/// workspace arena is created on the warm-up batch set and stays flat —
/// same workspace count, same resident element footprint — over further
/// evaluations (including a different bit config).
#[test]
fn eval_config_workspace_is_flat_after_warmup() {
    let dir = std::env::temp_dir().join(format!("autoq_plan_ws_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let data = SynthDataset::new(42);
    let mut rt =
        Runtime::open_with_opts(&dir, BackendKind::Reference, Some(Parallelism::new(2))).unwrap();
    let meta = rt.manifest.model("cif10").unwrap().clone();
    let params = ParamStore::init(&meta.params, &mut Rng::new(42));
    let wbits = vec![5u8; meta.w_channels];
    let abits = vec![4u8; meta.a_channels];
    let runner = ModelRunner::new(meta, params).unwrap();

    // Warm-up: first batch set builds plans + workspaces.
    let warm = runner
        .eval_config(&mut rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 3)
        .unwrap();
    let stats0 = rt.scratch_stats("cif10_eval_quant").expect("planned executable");
    assert!(stats0.workspaces >= 1 && stats0.workspaces <= 2);
    assert!(stats0.f32_len > 0);

    // Steady state: repeat evals (same config, then a different one) must
    // not grow the arena.
    for round in 0..3 {
        let res = runner
            .eval_config(&mut rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 3)
            .unwrap();
        assert_eq!(res.accuracy.to_bits(), warm.accuracy.to_bits(), "round {round}");
        let stats = rt.scratch_stats("cif10_eval_quant").unwrap();
        assert_eq!(stats, stats0, "workspace grew on round {round}: {stats:?}");
    }
    let wb32 = vec![32u8; wbits.len()];
    let ab32 = vec![32u8; abits.len()];
    runner.eval_config(&mut rt, Mode::Quant, &wb32, &ab32, &data, Split::Val, 3).unwrap();
    let stats = rt.scratch_stats("cif10_eval_quant").unwrap();
    assert_eq!(stats, stats0, "different bit config must reuse the same workspaces");

    std::fs::remove_dir_all(&dir).ok();
}
