//! Integration: artifacts load and execute with the shapes the manifest
//! promises — on the reference backend unconditionally (builtin manifest,
//! zero artifacts), and on PJRT over the real AOT artifacts when
//! `AUTOQ_REQUIRE_ARTIFACTS=1` (which fails, rather than skips, if they
//! are not built).

use std::path::Path;

use autoq::runtime::{BackendKind, Runtime, Tensor, Value};

fn runtimes() -> Vec<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rts =
        vec![Runtime::open_with(&dir, BackendKind::Reference).expect("reference backend")];
    if std::env::var("AUTOQ_REQUIRE_ARTIFACTS").is_ok() {
        assert!(
            dir.join("manifest.json").exists(),
            "AUTOQ_REQUIRE_ARTIFACTS=1 but AOT artifacts not built (run `make artifacts`)"
        );
        rts.push(Runtime::open_with(&dir, BackendKind::Pjrt).expect("artifacts unloadable"));
    }
    rts
}

#[test]
fn manifest_lists_all_families() {
    for rt in runtimes() {
        for model in ["cif10", "res18", "sqnet", "monet"] {
            for fam in ["eval_quant", "eval_binar", "train_quant", "train_binar"] {
                assert!(
                    rt.manifest.artifact(&format!("{model}_{fam}")).is_ok(),
                    "{model}_{fam} missing ({})",
                    rt.backend_name()
                );
            }
            let m = rt.manifest.model(model).unwrap();
            assert!(m.w_channels > 0 && m.a_channels > 0);
            assert_eq!(
                m.layers.iter().map(|l| l.w_len).sum::<usize>(),
                m.w_channels,
                "layer w slices must tile the weight-bit vector"
            );
            assert_eq!(m.layers.iter().map(|l| l.a_len).sum::<usize>(), m.a_channels);
        }
        for s in [16, 17] {
            assert!(rt.manifest.artifact(&format!("ddpg_act_s{s}")).is_ok());
            assert!(rt.manifest.artifact(&format!("ddpg_update_s{s}")).is_ok());
        }
    }
}

#[test]
fn backends_agree_on_manifest_metadata() {
    // When the PJRT lane runs, the builtin zoo manifest must match the AOT
    // exporter's manifest.json layer for layer — the cross-backend
    // consistency contract.
    let rts = runtimes();
    if rts.len() < 2 {
        return; // reference-only lane: nothing to compare
    }
    let (reference, pjrt) = (&rts[0].manifest, &rts[1].manifest);
    for model in ["cif10", "res18", "sqnet", "monet"] {
        let a = reference.model(model).unwrap();
        let b = pjrt.model(model).unwrap();
        assert_eq!(a.w_channels, b.w_channels, "{model} w_channels");
        assert_eq!(a.a_channels, b.a_channels, "{model} a_channels");
        assert_eq!(a.total_macs, b.total_macs, "{model} total_macs");
        assert_eq!(a.layers.len(), b.layers.len(), "{model} layer count");
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.name, lb.name);
            assert_eq!(la.typ, lb.typ);
            assert_eq!((la.w_off, la.w_len, la.a_off, la.a_len), (lb.w_off, lb.w_len, lb.a_off, lb.a_len));
            assert_eq!(la.macs, lb.macs, "{model}/{}", la.name);
        }
        assert_eq!(a.params.len(), b.params.len());
        for (pa, pb) in a.params.iter().zip(&b.params) {
            assert_eq!(pa.name, pb.name);
            assert_eq!(pa.shape, pb.shape);
        }
    }
}

#[test]
fn ddpg_act_executes_and_bounds_actions() {
    for mut rt in runtimes() {
        let spec = rt.manifest.artifact("ddpg_act_s16").unwrap().clone();
        // Zero-initialized actor → sigmoid(0)*32 == 16 for every state.
        let inputs: Vec<Value> = spec
            .inputs
            .iter()
            .map(|t| Value::F32(Tensor::zeros(t.shape.clone())))
            .collect();
        let outs = rt.exec("ddpg_act_s16", &inputs).unwrap();
        assert_eq!(outs.len(), 1);
        let a = outs[0].as_f32().unwrap();
        assert_eq!(a.shape, vec![128, 1]);
        for &x in &a.data {
            assert!((x - 16.0).abs() < 1e-5, "zero actor must emit 16.0, got {x}");
        }
    }
}

#[test]
fn exec_validates_arity() {
    for mut rt in runtimes() {
        let err = match rt.exec::<Value>("ddpg_act_s16", &[]) {
            Err(e) => e,
            Ok(_) => panic!("expected arity error"),
        };
        assert!(err.to_string().contains("inputs"));
    }
}
