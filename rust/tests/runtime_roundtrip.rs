//! Integration: AOT artifacts load, compile and execute through PJRT with
//! the shapes the manifest promises.  Requires `make artifacts`; tests
//! self-skip when the artifacts are not built (e.g. plain CI runners).

use std::path::Path;

use autoq::runtime::{Runtime, Tensor};

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        // AUTOQ_REQUIRE_ARTIFACTS=1 turns the silent skip into a failure so
        // full-stack CI lanes can't go green without exercising the runtime.
        assert!(
            std::env::var("AUTOQ_REQUIRE_ARTIFACTS").is_err(),
            "AOT artifacts required but not built (run `make artifacts`)"
        );
        eprintln!("skipping: AOT artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(&dir).expect("artifacts present but unloadable"))
}

#[test]
fn manifest_lists_all_families() {
    let Some(rt) = runtime() else { return };
    for model in ["cif10", "res18", "sqnet", "monet"] {
        for fam in ["eval_quant", "eval_binar", "train_quant", "train_binar"] {
            assert!(
                rt.manifest.artifact(&format!("{model}_{fam}")).is_ok(),
                "{model}_{fam} missing"
            );
        }
        let m = rt.manifest.model(model).unwrap();
        assert!(m.w_channels > 0 && m.a_channels > 0);
        assert_eq!(
            m.layers.iter().map(|l| l.w_len).sum::<usize>(),
            m.w_channels,
            "layer w slices must tile the weight-bit vector"
        );
        assert_eq!(m.layers.iter().map(|l| l.a_len).sum::<usize>(), m.a_channels);
    }
    for s in [16, 17] {
        assert!(rt.manifest.artifact(&format!("ddpg_act_s{s}")).is_ok());
        assert!(rt.manifest.artifact(&format!("ddpg_update_s{s}")).is_ok());
    }
}

#[test]
fn ddpg_act_executes_and_bounds_actions() {
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.artifact("ddpg_act_s16").unwrap().clone();
    // Zero-initialized actor → sigmoid(0)*32 == 16 for every state.
    let inputs: Vec<xla::Literal> = spec
        .inputs
        .iter()
        .map(|t| Tensor::zeros(t.shape.clone()).to_literal().unwrap())
        .collect();
    let outs = rt.exec("ddpg_act_s16", &inputs).unwrap();
    assert_eq!(outs.len(), 1);
    let a = Tensor::from_literal(&outs[0]).unwrap();
    assert_eq!(a.shape, vec![128, 1]);
    for &x in &a.data {
        assert!((x - 16.0).abs() < 1e-5, "zero actor must emit 16.0, got {x}");
    }
}

#[test]
fn exec_validates_arity() {
    let Some(mut rt) = runtime() else { return };
    let err = match rt.exec::<xla::Literal>("ddpg_act_s16", &[]) { Err(e) => e, Ok(_) => panic!("expected arity error") };
    assert!(err.to_string().contains("inputs"));
}
