//! Integer-kernel contracts (DESIGN.md §Integer kernels):
//!
//! * **Weight-grid exactness**: the per-channel i8 codes × scales from
//!   `quantize_w_i8` reproduce the fake-quant f32 weights bit-for-bit —
//!   the int path's weights carry *zero* extra error.
//! * **Tolerance contract**: quantize → pack → gemm → dequantize stays
//!   within the bound proven in the `qgemm` module docs against a
//!   sequential-f32 fake-quant oracle, across randomized shapes including
//!   edge tiles, all-zero rows/channels and pruned (0-bit) channels.
//! * **Nibble packing**: the bit-packed int4 kernel is bit-identical to
//!   the byte-wide int8 kernel whenever every channel fits a nibble.
//! * **Model level**: zoo-model `EvalResult`s under the int path agree
//!   with the forced-f32 reference at wbits ∈ {2, 4, 8}, and repeat int
//!   evals are byte-deterministic.

use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::reference::kernels::{
    qgemm_into, quantize_rows_i8, quantize_weights_alloc, set_int_kernels_enabled, wrep_with,
    WRep,
};
use autoq::runtime::reference::quantize::quantize_rows;
use autoq::runtime::{BackendKind, Parallelism, Runtime};
use autoq::util::rng::Rng;

/// Transpose a row-major `(rest, cout)` weight into channel-major
/// `(cout, rest)` and fake-quantize each channel row — the f32 oracle the
/// int path is specified against.
fn fake_quant_channel_major(w: &[f32], rest: usize, cout: usize, bits: &[f32]) -> Vec<f32> {
    let mut wfq = vec![0.0f32; rest * cout];
    for co in 0..cout {
        for r in 0..rest {
            wfq[co * rest + r] = w[r * cout + co];
        }
    }
    quantize_rows(&mut wfq, cout, rest, bits, false);
    wfq
}

/// The per-element bound from the `qgemm` module docs:
/// `k·maxa_i·maxw_j·(1/254 + (k + 4)·2⁻²³)`.
fn tolerance_bound(k: usize, maxa: f64, maxw: f64) -> f64 {
    k as f64 * maxa * maxw * (1.0 / 254.0 + (k as f64 + 4.0) * (2.0f64).powi(-23))
}

fn max_abs(xs: &[f32]) -> f64 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64
}

#[test]
fn weight_codes_reproduce_fake_quant_bitwise() {
    let mut rng = Rng::new(0xA11CE);
    for trial in 0..200 {
        let rest = 1 + rng.below(33);
        let cout = 1 + rng.below(9);
        let mut w = vec![0.0f32; rest * cout];
        rng.fill_normal_f32(&mut w, 1.0);
        // All-zero channels exercise the scale = 0-free (1.0) grid branch.
        if cout > 1 && rng.below(3) == 0 {
            let co = rng.below(cout);
            for r in 0..rest {
                w[r * cout + co] = 0.0;
            }
        }
        let bits: Vec<f32> = (0..cout)
            .map(|_| match rng.below(10) {
                0 => 0.0,  // pruned
                1 => -1.3, // rounds below zero → pruned
                2 => 7.6,  // rounds to 8, the i8 ceiling
                b => (b - 2) as f32,
            })
            .collect();
        let wfq = fake_quant_channel_major(&w, rest, cout, &bits);
        let (q8, s8) = quantize_weights_alloc(&w, rest, cout, &bits, WRep::I8);
        for co in 0..cout {
            for r in 0..rest {
                let dq = q8[co * rest + r] as f32 * s8[co];
                assert_eq!(
                    dq.to_bits(),
                    wfq[co * rest + r].to_bits(),
                    "trial={trial} co={co} r={r}: {dq} vs {}",
                    wfq[co * rest + r]
                );
            }
        }
    }
}

#[test]
fn packed_nibble_kernel_matches_bytewide_kernel() {
    let mut rng = Rng::new(0x4444);
    for trial in 0..80 {
        let m = 1 + rng.below(4);
        let k = 1 + rng.below(40); // odd k exercises the padded tail nibble
        let n = 1 + rng.below(10);
        let mut a = vec![0.0f32; m * k];
        let mut w = vec![0.0f32; k * n];
        rng.fill_normal_f32(&mut a, 1.0);
        rng.fill_normal_f32(&mut w, 0.7);
        let bits: Vec<f32> = (0..n).map(|_| rng.below(5) as f32).collect(); // 0..=4
        assert_eq!(wrep_with(true, &bits, false), WRep::I4);
        let mut qa = vec![0i8; m * k];
        let mut sa = vec![0.0f32; m];
        quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
        let (q8, s8) = quantize_weights_alloc(&w, k, n, &bits, WRep::I8);
        let (q4, s4) = quantize_weights_alloc(&w, k, n, &bits, WRep::I4);
        assert_eq!(s8, s4, "trial={trial}");
        let mut o8 = vec![f32::NAN; m * n];
        let mut o4 = vec![f32::NAN; m * n];
        qgemm_into(&mut o8, &qa, &sa, &q8, &s8, m, k, n, false);
        qgemm_into(&mut o4, &qa, &sa, &q4, &s4, m, k, n, true);
        for (e, (x, y)) in o8.iter().zip(&o4).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "trial={trial} ({m},{k},{n}) elem {e}");
        }
    }
}

#[test]
fn int_gemm_respects_the_documented_tolerance() {
    let mut rng = Rng::new(0xBEEF);
    // Directed edge shapes (single element, single row/col, dot-chunk
    // remainders, an n past the MC chunk) plus random ones.
    let mut shapes = vec![(1, 1, 1), (1, 7, 1), (3, 1, 5), (1, 257, 3), (2, 33, 4), (2, 2, 130)];
    for _ in 0..60 {
        shapes.push((1 + rng.below(5), 1 + rng.below(64), 1 + rng.below(12)));
    }
    for (ti, &(m, k, n)) in shapes.iter().enumerate() {
        let mut a = vec![0.0f32; m * k];
        rng.fill_normal_f32(&mut a, 1.0);
        if m > 1 && rng.below(3) == 0 {
            let i = rng.below(m);
            a[i * k..(i + 1) * k].fill(0.0); // all-zero activation row
        }
        let mut w = vec![0.0f32; k * n];
        rng.fill_normal_f32(&mut w, 0.7);
        if n > 1 && rng.below(3) == 0 {
            let co = rng.below(n);
            for r in 0..k {
                w[r * n + co] = 0.0; // all-zero weight channel
            }
        }
        for low_bit in [false, true] {
            let bits: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.below(8) == 0 {
                        return 0.0; // pruned channel
                    }
                    (1 + rng.below(if low_bit { 4 } else { 8 })) as f32
                })
                .collect();
            let rep = wrep_with(true, &bits, false);
            assert_ne!(rep, WRep::F32, "bits ≤ 8 must dispatch an int kernel");
            let (qw, sw) = quantize_weights_alloc(&w, k, n, &bits, rep);
            let mut qa = vec![0i8; m * k];
            let mut sa = vec![0.0f32; m];
            quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
            let mut out = vec![f32::NAN; m * n];
            qgemm_into(&mut out, &qa, &sa, &qw, &sw, m, k, n, rep == WRep::I4);
            let wfq = fake_quant_channel_major(&w, k, n, &bits);
            for i in 0..m {
                let maxa = max_abs(&a[i * k..(i + 1) * k]);
                for j in 0..n {
                    // Sequential f32 accumulation — the reference the f32
                    // kernels produce and the bound is stated against.
                    let mut r = 0.0f32;
                    for t in 0..k {
                        r += a[i * k + t] * wfq[j * k + t];
                    }
                    let maxw = max_abs(&wfq[j * k..(j + 1) * k]);
                    let bound = tolerance_bound(k, maxa, maxw);
                    let diff = (out[i * n + j] as f64 - r as f64).abs();
                    assert!(
                        diff <= bound,
                        "shape {ti} ({m},{k},{n}) {rep:?} [{i}][{j}]: \
                         |{} - {r}| = {diff} > {bound}",
                        out[i * n + j]
                    );
                }
            }
        }
    }
}

/// Model-level agreement on the zoo: int-path `EvalResult`s vs the
/// forced-f32 reference at uniform wbits ∈ {2, 4, 8}.  The loss bound is
/// the discriminative one (garbage logits shift cross-entropy far more
/// than the re-quantization error budget); the repeat-eval assertion pins
/// the int path's byte-determinism.  Two models keep the runtime sane
/// while covering plain conv+fc (cif10) and squeeze blocks (sqnet).
#[test]
fn zoo_eval_agreement_across_int_and_f32_paths() {
    let dir = std::env::temp_dir().join(format!("autoq_intk_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let data = SynthDataset::new(42);
    let mut rt =
        Runtime::open_with_opts(&dir, BackendKind::Reference, Some(Parallelism::new(2))).unwrap();
    for model in ["cif10", "sqnet"] {
        let meta = rt.manifest.model(model).unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(42));
        let runner = ModelRunner::new(meta.clone(), params).unwrap();
        let abits = vec![4u8; meta.a_channels];
        for wb in [2u8, 4, 8] {
            let wbits = vec![wb; meta.w_channels];
            let mut eval = |rt: &mut Runtime| {
                runner
                    .eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 2)
                    .unwrap()
            };
            let prev = set_int_kernels_enabled(false);
            let reference = eval(&mut rt);
            set_int_kernels_enabled(true);
            let int1 = eval(&mut rt);
            let int2 = eval(&mut rt);
            set_int_kernels_enabled(prev);
            assert_eq!(
                int1.accuracy.to_bits(),
                int2.accuracy.to_bits(),
                "{model} wb={wb}: int path must be deterministic"
            );
            assert_eq!(int1.loss.to_bits(), int2.loss.to_bits(), "{model} wb={wb}");
            assert_eq!(int1.images, reference.images, "{model} wb={wb}");
            assert!(
                (int1.accuracy - reference.accuracy).abs() <= 0.1,
                "{model} wb={wb}: accuracy {} vs f32 {}",
                int1.accuracy,
                reference.accuracy
            );
            assert!(
                (int1.loss - reference.loss).abs() <= 0.1 * (1.0 + reference.loss.abs()),
                "{model} wb={wb}: loss {} vs f32 {}",
                int1.loss,
                reference.loss
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
