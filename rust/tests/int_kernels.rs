//! Integer-kernel contracts (DESIGN.md §Integer kernels):
//!
//! * **Weight-grid exactness**: the per-channel i8 codes × scales from
//!   `quantize_w_i8` reproduce the fake-quant f32 weights bit-for-bit —
//!   the int path's weights carry *zero* extra error.
//! * **Tolerance contract**: quantize → pack → gemm → dequantize stays
//!   within the bound proven in the `qgemm` module docs against a
//!   sequential-f32 fake-quant oracle, across randomized shapes including
//!   edge tiles, all-zero rows/channels and pruned (0-bit) channels.
//! * **Nibble packing**: the bit-packed int4 kernel is bit-identical to
//!   the byte-wide int8 kernel whenever every channel fits a nibble.
//! * **Model level**: zoo-model `EvalResult`s under the int path agree
//!   with the forced-f32 reference at wbits ∈ {2, 4, 8}, and repeat int
//!   evals are byte-deterministic.
//! * **Depthwise**: the per-channel int dwconv kernel obeys the same
//!   tolerance contract with `k_eff = k²`, and monet (the dwconv zoo
//!   model) agrees across the int and f32 paths end to end.
//! * **SIMD identity**: the AVX2 integer inner loops are bit-identical to
//!   the scalar ones — at the kernel layer and through a full model eval.

use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::reference::kernels::{
    qgemm_into, quantize_rows_i8, quantize_weights_alloc, set_int_kernels_enabled,
    set_simd_int_enabled, wrep_with, WRep,
};
use autoq::runtime::reference::nn::{self, Dims};
use autoq::runtime::reference::quantize::quantize_rows;
use autoq::runtime::{BackendKind, Parallelism, Runtime};
use autoq::util::rng::Rng;

/// Transpose a row-major `(rest, cout)` weight into channel-major
/// `(cout, rest)` and fake-quantize each channel row — the f32 oracle the
/// int path is specified against.
fn fake_quant_channel_major(w: &[f32], rest: usize, cout: usize, bits: &[f32]) -> Vec<f32> {
    let mut wfq = vec![0.0f32; rest * cout];
    for co in 0..cout {
        for r in 0..rest {
            wfq[co * rest + r] = w[r * cout + co];
        }
    }
    quantize_rows(&mut wfq, cout, rest, bits, false);
    wfq
}

/// The per-element bound from the `qgemm` module docs:
/// `k·maxa_i·maxw_j·(1/254 + (k + 4)·2⁻²³)`.
fn tolerance_bound(k: usize, maxa: f64, maxw: f64) -> f64 {
    k as f64 * maxa * maxw * (1.0 / 254.0 + (k as f64 + 4.0) * (2.0f64).powi(-23))
}

fn max_abs(xs: &[f32]) -> f64 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64
}

#[test]
fn weight_codes_reproduce_fake_quant_bitwise() {
    let mut rng = Rng::new(0xA11CE);
    for trial in 0..200 {
        let rest = 1 + rng.below(33);
        let cout = 1 + rng.below(9);
        let mut w = vec![0.0f32; rest * cout];
        rng.fill_normal_f32(&mut w, 1.0);
        // All-zero channels exercise the scale = 0-free (1.0) grid branch.
        if cout > 1 && rng.below(3) == 0 {
            let co = rng.below(cout);
            for r in 0..rest {
                w[r * cout + co] = 0.0;
            }
        }
        let bits: Vec<f32> = (0..cout)
            .map(|_| match rng.below(10) {
                0 => 0.0,  // pruned
                1 => -1.3, // rounds below zero → pruned
                2 => 7.6,  // rounds to 8, the i8 ceiling
                b => (b - 2) as f32,
            })
            .collect();
        let wfq = fake_quant_channel_major(&w, rest, cout, &bits);
        let (q8, s8) = quantize_weights_alloc(&w, rest, cout, &bits, WRep::I8);
        for co in 0..cout {
            for r in 0..rest {
                let dq = q8[co * rest + r] as f32 * s8[co];
                assert_eq!(
                    dq.to_bits(),
                    wfq[co * rest + r].to_bits(),
                    "trial={trial} co={co} r={r}: {dq} vs {}",
                    wfq[co * rest + r]
                );
            }
        }
    }
}

#[test]
fn packed_nibble_kernel_matches_bytewide_kernel() {
    let mut rng = Rng::new(0x4444);
    for trial in 0..80 {
        let m = 1 + rng.below(4);
        let k = 1 + rng.below(40); // odd k exercises the padded tail nibble
        let n = 1 + rng.below(10);
        let mut a = vec![0.0f32; m * k];
        let mut w = vec![0.0f32; k * n];
        rng.fill_normal_f32(&mut a, 1.0);
        rng.fill_normal_f32(&mut w, 0.7);
        let bits: Vec<f32> = (0..n).map(|_| rng.below(5) as f32).collect(); // 0..=4
        assert_eq!(wrep_with(true, &bits, false), WRep::I4);
        let mut qa = vec![0i8; m * k];
        let mut sa = vec![0.0f32; m];
        quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
        let (q8, s8) = quantize_weights_alloc(&w, k, n, &bits, WRep::I8);
        let (q4, s4) = quantize_weights_alloc(&w, k, n, &bits, WRep::I4);
        assert_eq!(s8, s4, "trial={trial}");
        let mut o8 = vec![f32::NAN; m * n];
        let mut o4 = vec![f32::NAN; m * n];
        qgemm_into(&mut o8, &qa, &sa, &q8, &s8, m, k, n, false);
        qgemm_into(&mut o4, &qa, &sa, &q4, &s4, m, k, n, true);
        for (e, (x, y)) in o8.iter().zip(&o4).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "trial={trial} ({m},{k},{n}) elem {e}");
        }
    }
}

#[test]
fn int_gemm_respects_the_documented_tolerance() {
    let mut rng = Rng::new(0xBEEF);
    // Directed edge shapes (single element, single row/col, dot-chunk
    // remainders, an n past the MC chunk) plus random ones.
    let mut shapes = vec![(1, 1, 1), (1, 7, 1), (3, 1, 5), (1, 257, 3), (2, 33, 4), (2, 2, 130)];
    for _ in 0..60 {
        shapes.push((1 + rng.below(5), 1 + rng.below(64), 1 + rng.below(12)));
    }
    for (ti, &(m, k, n)) in shapes.iter().enumerate() {
        let mut a = vec![0.0f32; m * k];
        rng.fill_normal_f32(&mut a, 1.0);
        if m > 1 && rng.below(3) == 0 {
            let i = rng.below(m);
            a[i * k..(i + 1) * k].fill(0.0); // all-zero activation row
        }
        let mut w = vec![0.0f32; k * n];
        rng.fill_normal_f32(&mut w, 0.7);
        if n > 1 && rng.below(3) == 0 {
            let co = rng.below(n);
            for r in 0..k {
                w[r * n + co] = 0.0; // all-zero weight channel
            }
        }
        for low_bit in [false, true] {
            let bits: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.below(8) == 0 {
                        return 0.0; // pruned channel
                    }
                    (1 + rng.below(if low_bit { 4 } else { 8 })) as f32
                })
                .collect();
            let rep = wrep_with(true, &bits, false);
            assert_ne!(rep, WRep::F32, "bits ≤ 8 must dispatch an int kernel");
            let (qw, sw) = quantize_weights_alloc(&w, k, n, &bits, rep);
            let mut qa = vec![0i8; m * k];
            let mut sa = vec![0.0f32; m];
            quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
            let mut out = vec![f32::NAN; m * n];
            qgemm_into(&mut out, &qa, &sa, &qw, &sw, m, k, n, rep == WRep::I4);
            let wfq = fake_quant_channel_major(&w, k, n, &bits);
            for i in 0..m {
                let maxa = max_abs(&a[i * k..(i + 1) * k]);
                for j in 0..n {
                    // Sequential f32 accumulation — the reference the f32
                    // kernels produce and the bound is stated against.
                    let mut r = 0.0f32;
                    for t in 0..k {
                        r += a[i * k + t] * wfq[j * k + t];
                    }
                    let maxw = max_abs(&wfq[j * k..(j + 1) * k]);
                    let bound = tolerance_bound(k, maxa, maxw);
                    let diff = (out[i * n + j] as f64 - r as f64).abs();
                    assert!(
                        diff <= bound,
                        "shape {ti} ({m},{k},{n}) {rep:?} [{i}][{j}]: \
                         |{} - {r}| = {diff} > {bound}",
                        out[i * n + j]
                    );
                }
            }
        }
    }
}

/// Model-level agreement on the zoo: int-path `EvalResult`s vs the
/// forced-f32 reference at uniform wbits ∈ {2, 4, 8}.  The loss bound is
/// the discriminative one (garbage logits shift cross-entropy far more
/// than the re-quantization error budget); the repeat-eval assertion pins
/// the int path's byte-determinism.  Two models keep the runtime sane
/// while covering plain conv+fc (cif10) and squeeze blocks (sqnet).
/// The model-level tests flip process-global kernel switches (int
/// dispatch, SIMD); serialize them so a concurrent flip cannot change
/// another test's dispatch mid-eval.
static FLAG_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn zoo_eval_agreement_across_int_and_f32_paths() {
    let _flags = FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = std::env::temp_dir().join(format!("autoq_intk_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let data = SynthDataset::new(42);
    let mut rt =
        Runtime::open_with_opts(&dir, BackendKind::Reference, Some(Parallelism::new(2))).unwrap();
    for model in ["cif10", "sqnet"] {
        let meta = rt.manifest.model(model).unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(42));
        let runner = ModelRunner::new(meta.clone(), params).unwrap();
        let abits = vec![4u8; meta.a_channels];
        for wb in [2u8, 4, 8] {
            let wbits = vec![wb; meta.w_channels];
            let mut eval = |rt: &mut Runtime| {
                runner
                    .eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 2)
                    .unwrap()
            };
            let prev = set_int_kernels_enabled(false);
            let reference = eval(&mut rt);
            set_int_kernels_enabled(true);
            let int1 = eval(&mut rt);
            let int2 = eval(&mut rt);
            set_int_kernels_enabled(prev);
            assert_eq!(
                int1.accuracy.to_bits(),
                int2.accuracy.to_bits(),
                "{model} wb={wb}: int path must be deterministic"
            );
            assert_eq!(int1.loss.to_bits(), int2.loss.to_bits(), "{model} wb={wb}");
            assert_eq!(int1.images, reference.images, "{model} wb={wb}");
            assert!(
                (int1.accuracy - reference.accuracy).abs() <= 0.1,
                "{model} wb={wb}: accuracy {} vs f32 {}",
                int1.accuracy,
                reference.accuracy
            );
            assert!(
                (int1.loss - reference.loss).abs() <= 0.1 * (1.0 + reference.loss.abs()),
                "{model} wb={wb}: loss {} vs f32 {}",
                int1.loss,
                reference.loss
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Int depthwise conv vs a sequential-f32 fake-quant oracle: the qgemm
/// tolerance contract with `k_eff = k²` (the per-output tap count; edge
/// pixels sum fewer taps and the bound is monotone in the count).
/// Activation maxima are taken per (image, channel) — the granularity
/// `quantize_nhwc_i8` actually scales at.
#[test]
fn int_dwconv_respects_the_documented_tolerance() {
    let mut rng = Rng::new(0xDC0C);
    // Directed shapes: 1×1 minimum, stride-2, non-square, k > h, a k=5
    // window; then random ones with k ∈ {1, 3, 5}.
    let mut shapes = vec![
        (1usize, 1usize, 1usize, 1usize, 1usize, 1usize),
        (1, 4, 4, 3, 3, 1),
        (2, 5, 5, 2, 3, 2),
        (1, 7, 3, 4, 3, 1),
        (1, 2, 2, 1, 5, 1),
        (1, 8, 8, 1, 5, 2),
    ];
    for _ in 0..40 {
        shapes.push((
            1 + rng.below(2),
            1 + rng.below(8),
            1 + rng.below(8),
            1 + rng.below(6),
            1 + 2 * rng.below(3),
            1 + rng.below(2),
        ));
    }
    for (ti, &(n, h, w, c, k, s)) in shapes.iter().enumerate() {
        let d = Dims { n, h, w, c };
        let mut x = vec![0.0f32; d.elems()];
        rng.fill_normal_f32(&mut x, 1.0);
        if c > 1 && rng.below(3) == 0 {
            // All-zero (image, channel) slice → the scale-free grid branch.
            let ch = rng.below(c);
            for p in 0..n * h * w {
                x[p * c + ch] = 0.0;
            }
        }
        let mut wt = vec![0.0f32; k * k * c];
        rng.fill_normal_f32(&mut wt, 0.7);
        for low_bit in [false, true] {
            let bits: Vec<f32> = (0..c)
                .map(|_| {
                    if rng.below(8) == 0 {
                        return 0.0; // pruned channel
                    }
                    (1 + rng.below(if low_bit { 4 } else { 8 })) as f32
                })
                .collect();
            let rep = wrep_with(true, &bits, false);
            assert_ne!(rep, WRep::F32, "bits ≤ 8 must dispatch an int kernel");
            // (k,k,1,cin) row-major is a (rest = k², cout = cin) weight —
            // the shared WQ quantizer covers dwconv unchanged.
            let (qw, sw) = quantize_weights_alloc(&wt, k * k, c, &bits, rep);
            let (out, od) = nn::qdwconv2d(&x, d, &qw, &sw, rep == WRep::I4, k, s, None);
            // Oracle: fake-quant weights back in (k,k,1,cin) layout through
            // the sequential-f32 dwconv kernel.
            let wfq_cm = fake_quant_channel_major(&wt, k * k, c, &bits);
            let mut wfq_rm = vec![0.0f32; k * k * c];
            for ch in 0..c {
                for tap in 0..k * k {
                    wfq_rm[tap * c + ch] = wfq_cm[ch * k * k + tap];
                }
            }
            let (oref, od2) = nn::dwconv2d(&x, d, &wfq_rm, k, s);
            assert_eq!(od, od2, "shape {ti}");
            for ni in 0..n {
                for ch in 0..c {
                    let mut maxa = 0.0f64;
                    for p in 0..h * w {
                        maxa = maxa.max(x[(ni * h * w + p) * c + ch].abs() as f64);
                    }
                    let maxw = max_abs(&wfq_cm[ch * k * k..(ch + 1) * k * k]);
                    let bound = tolerance_bound(k * k, maxa, maxw);
                    for oy in 0..od.h {
                        for ox in 0..od.w {
                            let e = ((ni * od.h + oy) * od.w + ox) * c + ch;
                            let diff = (out[e] as f64 - oref[e] as f64).abs();
                            assert!(
                                diff <= bound,
                                "shape {ti} ({n},{h},{w},{c}) k{k} s{s} {rep:?} [{e}]: \
                                 |{} - {}| = {diff} > {bound}",
                                out[e],
                                oref[e]
                            );
                        }
                    }
                }
            }
        }
    }
}

/// SIMD-on vs SIMD-off byte identity at the kernel layer: the AVX2 dots
/// accumulate exactly in i32, so both int8 and nibble-packed int4 GEMMs
/// must reproduce the scalar loops bit-for-bit at every shape (ragged
/// tails included).  Trivially true where the SIMD path cannot engage —
/// both runs take the scalar loop.
#[test]
fn simd_and_scalar_integer_kernels_are_bit_identical() {
    let mut rng = Rng::new(0x51D);
    for trial in 0..40 {
        let m = 1 + rng.below(4);
        let k = 1 + rng.below(200); // spans several 32-lane blocks + tails
        let n = 1 + rng.below(8);
        let mut a = vec![0.0f32; m * k];
        let mut w = vec![0.0f32; k * n];
        rng.fill_normal_f32(&mut a, 1.0);
        rng.fill_normal_f32(&mut w, 0.7);
        let bits8: Vec<f32> = (0..n).map(|_| (1 + rng.below(8)) as f32).collect();
        let bits4: Vec<f32> = (0..n).map(|_| (1 + rng.below(4)) as f32).collect();
        let (q8, s8) = quantize_weights_alloc(&w, k, n, &bits8, WRep::I8);
        let (q4, s4) = quantize_weights_alloc(&w, k, n, &bits4, WRep::I4);
        let mut qa = vec![0i8; m * k];
        let mut sa = vec![0.0f32; m];
        quantize_rows_i8(&a, m, k, &mut qa, &mut sa);
        let mut run = |simd: bool| {
            let prev = set_simd_int_enabled(simd);
            let mut o8 = vec![f32::NAN; m * n];
            let mut o4 = vec![f32::NAN; m * n];
            qgemm_into(&mut o8, &qa, &sa, &q8, &s8, m, k, n, false);
            qgemm_into(&mut o4, &qa, &sa, &q4, &s4, m, k, n, true);
            set_simd_int_enabled(prev);
            (o8, o4)
        };
        let (on8, on4) = run(true);
        let (off8, off4) = run(false);
        for (e, (x, y)) in on8.iter().zip(&off8).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "trial={trial} i8 ({m},{k},{n}) elem {e}");
        }
        for (e, (x, y)) in on4.iter().zip(&off4).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "trial={trial} i4 ({m},{k},{n}) elem {e}");
        }
    }
}

/// Depthwise layers on the int path at model level: monet (the only zoo
/// model with dwconv blocks) must agree with the forced-f32 reference and
/// stay byte-deterministic — this pins the plan engine and the tree walk
/// dispatching int dwconv under the same shared `wrep` rule end to end.
#[test]
fn monet_dwconv_zoo_eval_agreement() {
    let _flags = FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = std::env::temp_dir().join(format!("autoq_intdw_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let data = SynthDataset::new(42);
    let mut rt =
        Runtime::open_with_opts(&dir, BackendKind::Reference, Some(Parallelism::new(2))).unwrap();
    let meta = rt.manifest.model("monet").unwrap().clone();
    assert!(meta.layers.iter().any(|l| l.typ == "dwconv"), "monet must carry dwconv layers");
    let params = ParamStore::init(&meta.params, &mut Rng::new(42));
    let runner = ModelRunner::new(meta.clone(), params).unwrap();
    let abits = vec![4u8; meta.a_channels];
    for wb in [4u8, 8] {
        let wbits = vec![wb; meta.w_channels];
        let mut eval = |rt: &mut Runtime| {
            runner.eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1).unwrap()
        };
        let prev = set_int_kernels_enabled(false);
        let reference = eval(&mut rt);
        set_int_kernels_enabled(true);
        let int1 = eval(&mut rt);
        let int2 = eval(&mut rt);
        set_int_kernels_enabled(prev);
        assert_eq!(
            int1.accuracy.to_bits(),
            int2.accuracy.to_bits(),
            "monet wb={wb}: int dwconv path must be deterministic"
        );
        assert_eq!(int1.loss.to_bits(), int2.loss.to_bits(), "monet wb={wb}");
        assert_eq!(int1.images, reference.images, "monet wb={wb}");
        assert!(
            (int1.accuracy - reference.accuracy).abs() <= 0.1,
            "monet wb={wb}: accuracy {} vs f32 {}",
            int1.accuracy,
            reference.accuracy
        );
        assert!(
            (int1.loss - reference.loss).abs() <= 0.1 * (1.0 + reference.loss.abs()),
            "monet wb={wb}: loss {} vs f32 {}",
            int1.loss,
            reference.loss
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// SIMD-on vs SIMD-off byte identity at model level: a full int-path zoo
/// eval (monet covers conv, fc and dwconv layers) must not move a single
/// bit when the SIMD dispatch flips.
#[test]
fn simd_toggle_preserves_eval_bytes() {
    let _flags = FLAG_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = std::env::temp_dir().join(format!("autoq_simdtg_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let data = SynthDataset::new(42);
    let mut rt =
        Runtime::open_with_opts(&dir, BackendKind::Reference, Some(Parallelism::new(2))).unwrap();
    let meta = rt.manifest.model("monet").unwrap().clone();
    let params = ParamStore::init(&meta.params, &mut Rng::new(7));
    let runner = ModelRunner::new(meta.clone(), params).unwrap();
    let wbits = vec![5u8; meta.w_channels];
    let abits = vec![4u8; meta.a_channels];
    let mut eval = |rt: &mut Runtime| {
        runner.eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1).unwrap()
    };
    let prev_int = set_int_kernels_enabled(true);
    let prev_simd = set_simd_int_enabled(true);
    let on = eval(&mut rt);
    set_simd_int_enabled(false);
    let off = eval(&mut rt);
    set_simd_int_enabled(prev_simd);
    set_int_kernels_enabled(prev_int);
    assert_eq!(
        on.accuracy.to_bits(),
        off.accuracy.to_bits(),
        "SIMD toggle changed eval accuracy bits"
    );
    assert_eq!(on.loss.to_bits(), off.loss.to_bits(), "SIMD toggle changed eval loss bits");
    std::fs::remove_dir_all(&dir).ok();
}
