//! Wire-format regression tests for the shard protocol's binary encoding
//! (DESIGN.md §Wire format): a representative exec request — res18
//! parameters + validation batches, the search hot path's payload — must
//! shrink at least 5× against the JSON encoding, and must round-trip with
//! every f32 bit pattern intact.
//!
//! The JSON side is *measured*, not materialized: one full frame for this
//! payload is hundreds of megabytes of text, so the test sums exact
//! per-set string lengths plus the envelope and separators instead of
//! building the whole string.

use autoq::data::synth::{Split, SynthDataset};
use autoq::models::ParamStore;
use autoq::runtime::shard::proto::{self, Request};
use autoq::runtime::shard::bin;
use autoq::runtime::Value;
use autoq::util::json::Json;
use autoq::util::rng::Rng;

/// The shared payload: 2 eval input sets in the exact row layout
/// `eval_config` dispatches — parameters and bit vectors shared across
/// sets (same `&Value` pointers, which is what the binary encoder
/// deduplicates), images/labels per set.
struct Payload {
    param_vals: Vec<Value>,
    per_set: Vec<(Value, Value)>,
    wb: Value,
    ab: Value,
}

impl Payload {
    fn build() -> Payload {
        let manifest = autoq::runtime::reference::builtin_manifest();
        let meta = manifest.model("res18").unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(7));
        let param_vals: Vec<Value> =
            params.tensors.iter().map(|t| Value::F32(t.clone())).collect();
        let data = SynthDataset::new(42);
        let (n, hw) = (meta.eval_batch, meta.image_hw);
        let per_set: Vec<(Value, Value)> = (0..2)
            .map(|i| {
                let batch = data.batch(Split::Val, (i * n) as u64, n);
                let img = Value::f32(vec![n, hw, hw, 3], batch.images);
                let lbl = Value::i32(vec![n], batch.labels);
                (img, lbl)
            })
            .collect();
        let wb = Value::f32(vec![meta.w_channels], vec![5.0; meta.w_channels]);
        let ab = Value::f32(vec![meta.a_channels], vec![4.0; meta.a_channels]);
        Payload { param_vals, per_set, wb, ab }
    }

    fn sets(&self) -> Vec<Vec<&Value>> {
        self.per_set
            .iter()
            .map(|(img, lbl)| {
                let mut row: Vec<&Value> = self.param_vals.iter().collect();
                row.push(img);
                row.push(lbl);
                row.push(&self.wb);
                row.push(&self.ab);
                row
            })
            .collect()
    }
}

/// Exact length of the full JSON exec frame for `sets`, computed without
/// allocating it: the empty-batches envelope, plus each set's own string
/// length, plus one comma between adjacent sets (the serializer emits no
/// whitespace, pinned by the envelope assertion).
fn json_frame_len(artifact: &str, sets: &[Vec<&Value>]) -> usize {
    let envelope = proto::exec_json::<&Value>(artifact, &[]).to_string();
    assert!(envelope.contains("\"batches\":[]"), "envelope layout changed: {envelope}");
    let body: usize = sets
        .iter()
        .map(|set| {
            Json::Arr(set.iter().map(|v| proto::value_to_json(v)).collect())
                .to_string()
                .len()
        })
        .sum();
    envelope.len() + body + sets.len().saturating_sub(1)
}

#[test]
fn binary_exec_request_is_at_least_5x_smaller_than_json() {
    let payload = Payload::build();
    let sets = payload.sets();
    let binary = bin::exec_bytes("res18_eval_quant", &sets);
    let json = json_frame_len("res18_eval_quant", &sets);
    let ratio = json as f64 / binary.len() as f64;
    assert!(
        ratio >= 5.0,
        "binary exec request must be >= 5x smaller than JSON: \
         json {json} bytes vs binary {} bytes ({ratio:.2}x)",
        binary.len()
    );
}

#[test]
fn binary_exec_request_roundtrips_bit_exactly() {
    let payload = Payload::build();
    let sets = payload.sets();
    let frame = bin::exec_bytes("res18_eval_quant", &sets);
    let Request::Exec { artifact, batches } = bin::request_from_bytes(&frame).unwrap() else {
        panic!("exec frame decoded as a different request");
    };
    assert_eq!(artifact, "res18_eval_quant");
    assert_eq!(batches.len(), sets.len());
    for (got_set, want_set) in batches.iter().zip(&sets) {
        assert_eq!(got_set.len(), want_set.len());
        for (got, want) in got_set.iter().zip(want_set.iter()) {
            assert_eq!(got.shape(), want.shape());
            match (got, want) {
                (Value::F32(g), Value::F32(w)) => {
                    assert_eq!(g.data.len(), w.data.len());
                    let diverged =
                        g.data.iter().zip(&w.data).any(|(a, b)| a.to_bits() != b.to_bits());
                    assert!(!diverged, "f32 bits changed across the binary codec");
                }
                (Value::I32 { data: g, .. }, Value::I32 { data: w, .. }) => {
                    assert_eq!(g, w, "i32 payload changed across the binary codec");
                }
                _ => panic!("dtype changed across the binary codec"),
            }
        }
    }
}
