//! Integration: the full search pipeline — episode walk, granularities,
//! protocols, baselines and fine-tuning.
//!
//! Runs unconditionally on the pure-Rust **reference backend** (no AOT
//! artifacts, no XLA library — every CI runner exercises real episodes).
//! Setting `AUTOQ_REQUIRE_ARTIFACTS=1` additionally runs every test body
//! against the PJRT backend over the real artifacts (and fails, rather
//! than skips, if they are not built).

use std::path::Path;

use autoq::baselines::{run_baseline, BaselineConfig, BaselinePolicy};
use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::ModelRunner;
use autoq::runtime::{BackendKind, Runtime};
use autoq::search::{run_search, Granularity, Protocol, SearchConfig};
use autoq::util::rng::Rng;

/// The runtimes to exercise: always the reference interpreter; plus PJRT
/// when the opt-in artifact lane is requested.
fn runtimes() -> Vec<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rts =
        vec![Runtime::open_with(&dir, BackendKind::Reference).expect("reference backend")];
    if std::env::var("AUTOQ_REQUIRE_ARTIFACTS").is_ok() {
        assert!(
            dir.join("manifest.json").exists(),
            "AUTOQ_REQUIRE_ARTIFACTS=1 but AOT artifacts not built (run `make artifacts`)"
        );
        rts.push(Runtime::open_with(&dir, BackendKind::Pjrt).expect("artifacts unloadable"));
    }
    rts
}

/// A lightly-trained cif10 runner (fast; accuracy need not be high for
/// structural assertions).
fn quick_runner(rt: &mut Runtime) -> ModelRunner {
    let meta = rt.manifest.model("cif10").unwrap().clone();
    let mut runner = ModelRunner::init(meta, &mut Rng::new(99));
    let data = SynthDataset::new(7);
    let mut cfg = autoq::finetune::TrainConfig::pretrain(8);
    cfg.eval_batches = 1;
    autoq::finetune::train(rt, &mut runner, &data, &cfg).unwrap();
    runner
}

fn quick_cfg(gran: Granularity, protocol: Protocol) -> SearchConfig {
    let mut cfg = SearchConfig::quick(Mode::Quant, protocol, gran);
    cfg.episodes = 2;
    cfg.warmup = 1;
    cfg.eval_batches = 1;
    cfg
}

/// Regression: `episodes == 0` used to fall through the episode loop and
/// panic on `best.expect(..)`.  Both entry layers must reject it as a
/// structured error instead — the `JobSpec` builder at `build()` time,
/// and `run_search`/`run_baseline` for callers that drive a
/// `SearchConfig`/`BaselineConfig` directly (repro tables, benches).
#[test]
fn zero_episode_search_errors_instead_of_panicking() {
    assert!(autoq::coordinator::JobSpec::search("cif10").episodes(0).build().is_err());

    for mut rt in runtimes() {
        let runner = quick_runner(&mut rt);
        let data = SynthDataset::new(7);
        let mut cfg = quick_cfg(Granularity::Channel, Protocol::accuracy_guaranteed());
        cfg.episodes = 0;
        cfg.warmup = 0;
        let err = run_search(&mut rt, &runner, &data, &cfg)
            .map(|_| ())
            .expect_err("zero episodes must be an error, not a panic");
        assert!(format!("{err:#}").contains("episode"), "unhelpful error: {err:#}");

        let mut bcfg = BaselineConfig::quick(
            BaselinePolicy::Amc,
            Mode::Quant,
            Protocol::accuracy_guaranteed(),
        );
        bcfg.episodes = 0;
        assert!(run_baseline(&mut rt, &runner, &data, &bcfg).is_err());
    }
}

#[test]
fn channel_search_produces_valid_config() {
    for mut rt in runtimes() {
        let runner = quick_runner(&mut rt);
        let data = SynthDataset::new(7);
        let res = run_search(
            &mut rt,
            &runner,
            &data,
            &quick_cfg(Granularity::Channel, Protocol::accuracy_guaranteed()),
        )
        .unwrap();
        let b = &res.best;
        assert_eq!(b.wbits.len(), runner.meta.w_channels);
        assert_eq!(b.abits.len(), runner.meta.a_channels);
        assert!(b.wbits.iter().all(|&x| x <= 32));
        assert!(b.reward.is_finite());
        assert!(b.accuracy >= 0.0 && b.accuracy <= 1.0);
        assert_eq!(res.history.len(), 2);
        assert_eq!(b.per_layer.len(), runner.meta.layers.len());
        // Variance-ordering constraint holds per layer (§3.2).
        let wvar = runner.weight_variances();
        for l in &runner.meta.layers {
            let bits = &b.wbits[l.w_off..l.w_off + l.w_len];
            let vars = &wvar[l.w_off..l.w_off + l.w_len];
            for x in 0..bits.len() {
                for y in 0..bits.len() {
                    if vars[x] > vars[y] {
                        assert!(
                            bits[x] >= bits[y],
                            "layer {}: var order violated ({x},{y})",
                            l.name
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn layer_granularity_is_uniform_within_layers() {
    for mut rt in runtimes() {
        let runner = quick_runner(&mut rt);
        let data = SynthDataset::new(7);
        let res = run_search(
            &mut rt,
            &runner,
            &data,
            &quick_cfg(Granularity::Layer, Protocol::accuracy_guaranteed()),
        )
        .unwrap();
        for l in &runner.meta.layers {
            let bits = &res.best.wbits[l.w_off..l.w_off + l.w_len];
            assert!(bits.iter().all(|&b| b == bits[0]), "layer {} not uniform", l.name);
        }
    }
}

#[test]
fn network_granularity_fixed_bits() {
    for mut rt in runtimes() {
        let runner = quick_runner(&mut rt);
        let data = SynthDataset::new(7);
        let res = run_search(
            &mut rt,
            &runner,
            &data,
            &quick_cfg(Granularity::Network(5), Protocol::resource_constrained(5.0)),
        )
        .unwrap();
        assert!(res.best.wbits.iter().all(|&b| b == 5));
        assert!(res.best.abits.iter().all(|&b| b == 5));
        assert_eq!(res.history.len(), 1, "network granularity needs no exploration");
    }
}

#[test]
fn rc_protocol_respects_algorithm1_budget() {
    for mut rt in runtimes() {
        let runner = quick_runner(&mut rt);
        let data = SynthDataset::new(7);
        let target = 4.0;
        let res = run_search(
            &mut rt,
            &runner,
            &data,
            &quick_cfg(Granularity::Layer, Protocol::resource_constrained(target)),
        )
        .unwrap();
        // Layer granularity applies goals verbatim, so the MAC-weighted mean
        // weight bit-width must meet the Algorithm-1 budget.
        let total: f64 = runner.meta.layers.iter().map(|l| l.macs as f64).sum();
        let spent: f64 = runner
            .meta
            .layers
            .iter()
            .map(|l| l.macs as f64 * res.best.wbits[l.w_off] as f64)
            .sum();
        let avg = spent / total;
        assert!(avg <= target + 0.5, "MAC-weighted avg bits {avg} exceeds target {target}");
    }
}

#[test]
fn baselines_respect_their_action_spaces() {
    for mut rt in runtimes() {
        let runner = quick_runner(&mut rt);
        let data = SynthDataset::new(7);

        // AMC: prune-or-8-bit weights, 8-bit activations.
        let mut cfg =
            BaselineConfig::quick(BaselinePolicy::Amc, Mode::Quant, Protocol::flop_reward());
        cfg.episodes = 2;
        cfg.warmup = 2;
        cfg.eval_batches = 1;
        let res = run_baseline(&mut rt, &runner, &data, &cfg).unwrap();
        assert!(res.best.wbits.iter().all(|&b| b == 0 || b == 8));
        assert!(res.best.abits.iter().all(|&b| b == 8));

        // ReLeQ: weights searched per layer, activations pinned at 8.
        let mut cfg = BaselineConfig::quick(
            BaselinePolicy::Releq,
            Mode::Quant,
            Protocol::accuracy_guaranteed(),
        );
        cfg.episodes = 2;
        cfg.warmup = 2;
        cfg.eval_batches = 1;
        let res = run_baseline(&mut rt, &runner, &data, &cfg).unwrap();
        assert!(res.best.abits.iter().all(|&b| b == 8));
        for l in &runner.meta.layers {
            let bits = &res.best.wbits[l.w_off..l.w_off + l.w_len];
            assert!(bits.iter().all(|&b| b == bits[0]), "releq must be layer-uniform");
        }
    }
}

#[test]
fn finetune_improves_or_holds_quantized_accuracy() {
    for mut rt in runtimes() {
        let mut runner = quick_runner(&mut rt);
        let data = SynthDataset::new(7);
        let wbits = vec![3u8; runner.meta.w_channels];
        let abits = vec![4u8; runner.meta.a_channels];
        let before = runner
            .eval_config(&mut rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1)
            .unwrap();
        let tc = autoq::finetune::TrainConfig {
            eval_batches: 1,
            ..autoq::finetune::TrainConfig::finetune(Mode::Quant, wbits, abits, 12)
        };
        let rep = autoq::finetune::train(&mut rt, &mut runner, &data, &tc).unwrap();
        assert!(
            rep.final_eval.accuracy >= before.accuracy - 0.05,
            "finetune regressed: {} -> {}",
            before.accuracy,
            rep.final_eval.accuracy
        );
    }
}

#[test]
fn binar_mode_runs_end_to_end() {
    for mut rt in runtimes() {
        let runner = quick_runner(&mut rt);
        let data = SynthDataset::new(7);
        let mut cfg = quick_cfg(Granularity::Channel, Protocol::accuracy_guaranteed());
        cfg.mode = Mode::Binar;
        let res = run_search(&mut rt, &runner, &data, &cfg).unwrap();
        assert!(res.best.reward.is_finite());
        assert!(res.best.accuracy >= 0.0);
    }
}
