//! End-to-end tests of the `autoq serve` daemon: the socket protocol
//! (submit → status → result → subscribe → shutdown), concurrent
//! scheduling, malformed-frame handling, signal-flag shutdown, the
//! no-orphan contract with the shard backend — and the acceptance
//! contract: a sweep run twice against one daemon reports cache hits on
//! the repeat and byte-identical reports to a daemon-free sweep.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::Stdio;
use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use autoq::coordinator::{Coordinator, JobSpec, Sweep};
use autoq::cost::Mode;
use autoq::runtime::{BackendKind, Parallelism};
use autoq::search::{Granularity, Protocol};
use autoq::serve::{run_sweep_via_daemon, DaemonClient, JobQueue, ServeConfig, Server};
use autoq::util::json::Json;

/// Point shard pools at the real `autoq` binary (same ordering contract as
/// tests/shard_backend.rs: first action of every test that may shard).
fn worker_exe() -> PathBuf {
    static EXE: OnceLock<PathBuf> = OnceLock::new();
    EXE.get_or_init(|| {
        let exe = PathBuf::from(env!("CARGO_BIN_EXE_autoq"));
        std::env::set_var("AUTOQ_WORKER_EXE", &exe);
        exe
    })
    .clone()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("autoq_serve_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Persist cheap (3-step) trained params so daemon workers load identical
/// bytes instead of auto-pretraining 300 steps mid-test.
fn seed_params(dir: &Path) {
    let mut coord = Coordinator::open_with(dir, Some(BackendKind::Reference)).unwrap();
    coord.run(&JobSpec::pretrain("cif10").steps(3).build().unwrap()).unwrap();
}

struct Daemon {
    addr: String,
    queue: Arc<JobQueue>,
    thread: JoinHandle<anyhow::Result<()>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
}

/// Bind on port 0 and run an in-process daemon on `backend`.
fn start_daemon(dir: &Path, workers: usize, backend: BackendKind, shard_workers: Option<usize>) -> Daemon {
    let cfg = ServeConfig {
        dir: dir.to_path_buf(),
        backend: Some(backend),
        threads: Some(Parallelism::new(2)),
        shard_workers,
        workers,
        ..ServeConfig::default()
    };
    let server = Server::bind("127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr().to_string();
    let queue = server.queue();
    let stop = server.stop_flag();
    let thread = std::thread::spawn(move || server.run());
    Daemon { addr, queue, thread, stop }
}

fn quick_eval() -> JobSpec {
    JobSpec::eval("cif10").batches(1).build().unwrap()
}

fn quick_search(seed: u64) -> JobSpec {
    JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Network(5))
        .episodes(2)
        .warmup(1)
        .eval_batches(1)
        .seed(seed)
        .build()
        .unwrap()
}

#[test]
fn e2e_submit_status_result_over_the_socket() {
    let dir = temp_dir("e2e");
    seed_params(&dir);
    let daemon = start_daemon(&dir, 1, BackendKind::Reference, None);
    let mut client = DaemonClient::connect(&daemon.addr).unwrap();

    assert_eq!(client.ping().unwrap(), std::process::id());

    let spec = quick_eval();
    let handle = client.submit(&spec).unwrap();
    assert_eq!(handle, "job-0");

    // Status for the whole queue names the job with its spec id.
    let status = client.status(None).unwrap();
    let rows = status.req("jobs").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].req("id").unwrap().as_str(), Some(spec.id().as_str()));

    // Blocking result: terminal state, verbatim report, cache counters in
    // the envelope (and meaningless zeros are fine — it's an fp32 eval).
    let row = client.result(&handle, true).unwrap();
    assert_eq!(row.req("state").unwrap().as_str(), Some("done"));
    let report = row.req("report").unwrap();
    assert_eq!(report.req("id").unwrap().as_str(), Some(spec.id().as_str()));
    assert!(report.get("eval").is_some(), "eval job must return an eval outcome");
    assert!(row.get("cache").is_some(), "cache counters ride the envelope");
    assert!(report.get("cache").is_none(), "…and never the report");

    // Unknown jobs are application errors, not dropped connections.
    assert!(client.result("job-99", false).is_err());
    assert!(client.status(Some("nope")).is_err());
    // The same connection keeps serving after those errors.
    assert_eq!(client.ping().unwrap(), std::process::id());

    client.shutdown(true).unwrap();
    daemon.thread.join().unwrap().unwrap();
    assert_eq!(daemon.queue.load(), (0, 0, 1));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_submissions_all_complete_with_shared_results() {
    let dir = temp_dir("conc");
    seed_params(&dir);
    // Two scheduler workers, three jobs: at least one pair runs
    // concurrently, the third queues behind the budget.
    let daemon = start_daemon(&dir, 2, BackendKind::Reference, None);
    let mut client = DaemonClient::connect(&daemon.addr).unwrap();

    let specs = [quick_search(7), quick_search(7), quick_eval()];
    let handles: Vec<String> =
        specs.iter().map(|s| client.submit(s).unwrap()).collect();
    let mut reports = Vec::new();
    for handle in &handles {
        let row = client.result(handle, true).unwrap();
        assert_eq!(row.req("state").unwrap().as_str(), Some("done"), "{handle}");
        reports.push(row.req("report").unwrap().clone());
    }
    // Identical specs (same seed) must produce identical reports, whether
    // or not their evals were served from the shared cache.
    let zero_secs = |j: &Json| {
        let mut j = j.clone();
        if let Json::Obj(m) = &mut j {
            m.insert("secs".to_string(), Json::Num(0.0));
        }
        j.to_string()
    };
    assert_eq!(zero_secs(&reports[0]), zero_secs(&reports[1]));

    client.shutdown(true).unwrap();
    daemon.thread.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Framing corruption drops that connection only; application-level junk
/// answers an error frame on a live connection.  Either way the daemon
/// keeps serving everyone else.
#[test]
fn malformed_frames_do_not_kill_the_daemon() {
    let dir = temp_dir("junk");
    seed_params(&dir);
    let daemon = start_daemon(&dir, 1, BackendKind::Reference, None);

    // 1. Oversized length prefix: the daemon rejects the frame and drops
    //    the connection (our read sees EOF).
    {
        let mut s = TcpStream::connect(&daemon.addr).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        s.flush().unwrap();
        let mut buf = [0u8; 16];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "corrupt framing must close the connection");
    }
    // 2. Valid frame, junk JSON body: same — the frame codec fails, the
    //    connection dies, the daemon survives.
    {
        let mut s = TcpStream::connect(&daemon.addr).unwrap();
        let junk = b"{not json!";
        s.write_all(&(junk.len() as u32).to_le_bytes()).unwrap();
        s.write_all(junk).unwrap();
        s.flush().unwrap();
        let mut buf = [0u8; 16];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "junk JSON must close the connection");
    }
    // 3. Well-formed JSON, unknown op: an application error — `{ok:false}`
    //    comes back and the SAME connection keeps working.
    {
        let mut client = DaemonClient::connect(&daemon.addr).unwrap();
        // (client helpers only send valid ops; drive the wire by hand)
        let mut s = TcpStream::connect(&daemon.addr).unwrap();
        let req = br#"{"op":"frobnicate"}"#;
        s.write_all(&(req.len() as u32).to_le_bytes()).unwrap();
        s.write_all(req).unwrap();
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        s.read_exact(&mut body).unwrap();
        let reply = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(reply.req("ok").unwrap().as_bool(), Some(false));
        // Invalid spec (episodes == 0): also an app error, connection lives.
        assert!(client.ping().is_ok());
    }
    // 4. After all that abuse, the daemon still runs jobs end to end.
    let mut client = DaemonClient::connect(&daemon.addr).unwrap();
    let handle = client.submit(&quick_eval()).unwrap();
    let row = client.result(&handle, true).unwrap();
    assert_eq!(row.req("state").unwrap().as_str(), Some("done"));

    client.shutdown(true).unwrap();
    daemon.thread.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite contract: a connection that goes silent is dropped by the
/// read timeout instead of pinning its handler forever, and the daemon
/// keeps serving fresh clients afterwards.
#[test]
fn idle_connections_are_dropped_and_the_daemon_keeps_serving() {
    let dir = temp_dir("idle");
    seed_params(&dir);
    let cfg = ServeConfig {
        dir: dir.clone(),
        backend: Some(BackendKind::Reference),
        threads: Some(Parallelism::new(2)),
        workers: 1,
        idle_timeout: Some(Duration::from_millis(150)),
        ..ServeConfig::default()
    };
    let server = Server::bind("127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr().to_string();
    let thread = std::thread::spawn(move || server.run());

    // Connect and say nothing: the daemon's read timeout fires and the
    // connection closes from the far side (our read sees EOF, not a hang).
    let mut silent = TcpStream::connect(&addr).unwrap();
    silent.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 8];
    let n = silent.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "idle connection must be dropped by the daemon");

    // …and the daemon still answers a fresh, talkative client.
    let mut client = DaemonClient::connect(&addr).unwrap();
    assert_eq!(client.ping().unwrap(), std::process::id());
    client.shutdown(true).unwrap();
    thread.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Subscribe streams started/episode/finished events; a late subscriber
/// gets the terminal event replayed.
#[test]
fn subscribe_streams_job_events() {
    let dir = temp_dir("events");
    seed_params(&dir);
    let daemon = start_daemon(&dir, 1, BackendKind::Reference, None);
    let mut client = DaemonClient::connect(&daemon.addr).unwrap();
    let handle = client.submit(&quick_search(3)).unwrap();

    // Raw subscribe on a second connection.
    let mut s = TcpStream::connect(&daemon.addr).unwrap();
    let req = format!(r#"{{"job":"{handle}","op":"subscribe"}}"#);
    s.write_all(&(req.len() as u32).to_le_bytes()).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    let mut read_json = |s: &mut TcpStream| {
        let mut len = [0u8; 4];
        s.read_exact(&mut len).unwrap();
        let mut body = vec![0u8; u32::from_le_bytes(len) as usize];
        s.read_exact(&mut body).unwrap();
        Json::parse(std::str::from_utf8(&body).unwrap()).unwrap()
    };
    let ack = read_json(&mut s);
    assert_eq!(ack.req("ok").unwrap().as_bool(), Some(true));
    let mut kinds = Vec::new();
    loop {
        let ev = read_json(&mut s);
        let kind = ev.req("event").unwrap().as_str().unwrap().to_string();
        let done = kind == "finished";
        kinds.push(kind);
        if done {
            assert_eq!(ev.req("ok").unwrap().as_bool(), Some(true));
            assert!(ev.get("report").is_some());
            assert!(ev.get("cache").is_some());
            break;
        }
    }
    assert!(kinds.contains(&"episode".to_string()), "events: {kinds:?}");
    assert_eq!(kinds.last().map(String::as_str), Some("finished"));

    // Late subscriber: terminal event replays immediately.
    let mut s2 = TcpStream::connect(&daemon.addr).unwrap();
    s2.write_all(&(req.len() as u32).to_le_bytes()).unwrap();
    s2.write_all(req.as_bytes()).unwrap();
    let ack = read_json(&mut s2);
    assert_eq!(ack.req("ok").unwrap().as_bool(), Some(true));
    let ev = read_json(&mut s2);
    assert_eq!(ev.req("event").unwrap().as_str(), Some("finished"));

    client.shutdown(true).unwrap();
    daemon.thread.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance contract: one daemon, the same sweep grid twice — the
/// repeat is served with >0 cache hits, and every report (both runs) is
/// byte-identical to a daemon-free `Sweep::run` of the same grid.
#[test]
fn sweep_twice_against_one_daemon_hits_and_stays_byte_identical() {
    let dir = temp_dir("sweep");
    seed_params(&dir);

    let grid = |out: &str| Sweep {
        protocols: vec![Protocol::resource_constrained(5.0), Protocol::accuracy_guaranteed()],
        granularities: vec![Granularity::Network(4)],
        episodes: 4,
        warmup: 1,
        eval_batches: 2,
        base_seed: 21,
        workers: 2,
        out_dir: Some(dir.join(out)),
        backend: Some(BackendKind::Reference),
        threads: Some(Parallelism::new(1)),
        ..Sweep::default()
    };

    // Reports as id → secs-zeroed JSON bytes.
    let canon = |out: &str| -> Vec<(String, String)> {
        let mut rows: Vec<(String, String)> = std::fs::read_dir(dir.join(out))
            .unwrap()
            .map(|e| {
                let path = e.unwrap().path();
                let mut j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
                if let Json::Obj(m) = &mut j {
                    m.insert("secs".to_string(), Json::Num(0.0));
                }
                (path.file_name().unwrap().to_string_lossy().into_owned(), j.to_string())
            })
            .collect();
        rows.sort();
        rows
    };

    // Daemon-free baseline.
    grid("local").run(&dir).unwrap();
    let want = canon("local");
    assert_eq!(want.len(), 2, "grid must expand to two cells");

    // One daemon, same grid twice.
    let daemon = start_daemon(&dir, 2, BackendKind::Reference, None);
    let r1 = run_sweep_via_daemon(&daemon.addr, &grid("warm1")).unwrap();
    assert!(r1.failures.is_empty(), "{:?}", r1.failures);
    let r2 = run_sweep_via_daemon(&daemon.addr, &grid("warm2")).unwrap();
    assert!(r2.failures.is_empty(), "{:?}", r2.failures);

    assert_eq!(canon("warm1"), want, "first daemon sweep diverged from local");
    assert_eq!(canon("warm2"), want, "second daemon sweep diverged from local");
    assert!(
        r2.cache.0 > 0,
        "second sweep must be served with cache hits (got {:?})",
        r2.cache
    );
    assert_eq!(r2.cache.1, 0, "a byte-identical repeat must add no misses");

    let mut client = DaemonClient::connect(&daemon.addr).unwrap();
    client.shutdown(true).unwrap();
    daemon.thread.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// The signal path minus the signal: tripping the server's stop flag (what
/// SIGINT/SIGTERM do through `util::signal`) stops the accept loop and
/// shuts the queue down without a client having to ask.
#[test]
fn stop_flag_shuts_the_daemon_down() {
    let dir = temp_dir("stop");
    seed_params(&dir);
    let daemon = start_daemon(&dir, 1, BackendKind::Reference, None);
    let mut client = DaemonClient::connect(&daemon.addr).unwrap();
    assert!(client.ping().is_ok());

    daemon.stop.store(true, std::sync::atomic::Ordering::SeqCst);
    daemon.thread.join().unwrap().unwrap();
    assert!(daemon.queue.shutting_down());
    assert!(
        daemon.queue.submit(quick_eval(), 0).is_err(),
        "submissions must be rejected after a signal shutdown"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The real signal path, end to end: spawn the `autoq serve` binary, talk
/// to it over its advertised address, SIGTERM it, and require a clean
/// (code 0) exit — the satellite contract for Ctrl-C'd daemons.
#[cfg(unix)]
#[test]
fn serve_binary_exits_cleanly_on_sigterm() {
    let exe = worker_exe();
    let dir = temp_dir("sig");
    seed_params(&dir);
    let mut child = std::process::Command::new(&exe)
        .args(["serve", "--listen", "127.0.0.1:0", "--workers", "1", "--backend", "reference"])
        .env("AUTOQ_ARTIFACTS", &dir)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    // The first stdout line advertises the resolved port-0 address.
    let mut line = String::new();
    BufReader::new(child.stdout.take().unwrap()).read_line(&mut line).unwrap();
    let addr = line.trim().rsplit(' ').next().unwrap_or("").to_string();
    assert!(line.contains("listening on"), "unexpected banner: {line:?}");

    let mut client = DaemonClient::connect(&addr).unwrap();
    assert_eq!(client.ping().unwrap(), child.id());
    let handle = client.submit(&quick_eval()).unwrap();
    let row = client.result(&handle, true).unwrap();
    assert_eq!(row.req("state").unwrap().as_str(), Some("done"));

    let killed = std::process::Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .unwrap();
    assert!(killed.success());
    let deadline = Instant::now() + Duration::from_secs(10);
    let status = loop {
        if let Some(st) = child.try_wait().unwrap() {
            break st;
        }
        assert!(Instant::now() < deadline, "daemon did not exit after SIGTERM");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "SIGTERM must drain and exit 0, got {status:?}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The no-orphan contract on the shard backend: a daemon whose workers own
/// shard subprocess pools must leave zero `autoq worker` processes behind
/// after a drain shutdown.
#[test]
fn shard_daemon_drains_without_orphaning_workers() {
    let exe = worker_exe();
    let dir = temp_dir("shard");
    seed_params(&dir);
    let daemon = start_daemon(&dir, 1, BackendKind::Shard, Some(2));
    let mut client = DaemonClient::connect(&daemon.addr).unwrap();

    let handle = client.submit(&quick_eval()).unwrap();
    let row = client.result(&handle, true).unwrap();
    assert_eq!(row.req("state").unwrap().as_str(), Some("done"));

    client.shutdown(true).unwrap();
    daemon.thread.join().unwrap().unwrap();

    // Every shard subprocess must be gone once run() returns (their pipes
    // closed on Coordinator drop; give slow exits a moment).
    #[cfg(target_os = "linux")]
    {
        let exe_name = exe.to_string_lossy().into_owned();
        let orphans = |deadline: Instant| -> Vec<String> {
            loop {
                let mut found = Vec::new();
                for entry in std::fs::read_dir("/proc").unwrap().flatten() {
                    let pid = entry.file_name().to_string_lossy().into_owned();
                    if !pid.chars().all(|c| c.is_ascii_digit()) {
                        continue;
                    }
                    let Ok(cmd) = std::fs::read(format!("/proc/{pid}/cmdline")) else {
                        continue;
                    };
                    let cmd = String::from_utf8_lossy(&cmd).replace('\0', " ");
                    let Ok(stat) = std::fs::read_to_string(format!("/proc/{pid}/stat")) else {
                        continue;
                    };
                    let ppid_ours = stat
                        .split_whitespace()
                        .nth(3)
                        .map(|p| p == std::process::id().to_string())
                        .unwrap_or(false);
                    if ppid_ours && cmd.contains(&exe_name) && cmd.contains(" worker") {
                        found.push(format!("{pid}: {cmd}"));
                    }
                }
                if found.is_empty() || Instant::now() > deadline {
                    return found;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        };
        let left = orphans(Instant::now() + Duration::from_secs(5));
        assert!(left.is_empty(), "orphaned shard workers: {left:?}");
    }
    let _ = exe;
    std::fs::remove_dir_all(&dir).ok();
}
