//! Determinism and cross-backend agreement.
//!
//! * The same `JobSpec` + seed through two freshly-opened `Coordinator`s
//!   yields byte-identical `JobReport` JSON (wall-clock `secs` zeroed —
//!   the only intentionally non-deterministic field).
//! * The reference backend's parallel eval path is **byte-identical** to
//!   the serial interpreter at every thread count — both at the
//!   `eval_config` level and for whole search `JobReport`s.
//! * With `AUTOQ_REQUIRE_ARTIFACTS=1` (the opt-in PJRT lane), the
//!   reference interpreter and the PJRT backend agree within tolerance
//!   for identical inputs on eval, `train_step` and the DDPG update.

use std::path::{Path, PathBuf};

use autoq::agent::{DdpgAgent, DdpgHyper, ReplayBuffer, Transition};
use autoq::coordinator::{Coordinator, JobSpec};
use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::{BackendKind, Parallelism, Runtime};
use autoq::search::{Granularity, Protocol};
use autoq::util::rng::Rng;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("autoq_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn same_jobspec_and_seed_yield_byte_identical_reports() {
    let dir = temp_dir("determinism");

    // Seed the artifact dir with deterministic pretrained params once, so
    // both search runs load the same persisted weights.
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        let spec = JobSpec::pretrain("cif10").steps(4).build().unwrap();
        coord.run(&spec).unwrap();
    }

    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Channel)
        .episodes(2)
        .warmup(1)
        .eval_batches(1)
        .seed(5)
        .build()
        .unwrap();

    // Two independent coordinators — fresh runtime, fresh runner cache —
    // model a process restart.
    let mut jsons = Vec::new();
    for _ in 0..2 {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0; // wall-clock is the one legitimately varying field
        jsons.push(report.to_json().to_string());
    }
    assert_eq!(jsons[0], jsons[1], "JobReport JSON must be byte-identical");
    // Sanity: the report actually carries a searched config.
    assert!(jsons[0].contains("\"wbits\""));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pretrain_then_eval_is_deterministic_across_coordinators() {
    let dir = temp_dir("det_eval");
    let run = || -> String {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        let spec = JobSpec::pretrain("cif10").steps(3).persist(false).build().unwrap();
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0;
        report.to_json().to_string()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "pretrain reports must replay bit-identically");
    std::fs::remove_dir_all(&dir).ok();
}

/// The parallel eval path must be *byte*-identical to the serial
/// interpreter: same params + data through runtimes at 1/2/4 threads give
/// `EvalResult`s whose f64 bit patterns match exactly.
#[test]
fn reference_eval_is_byte_identical_across_thread_counts() {
    let dir = temp_dir("par_eval");
    let data = SynthDataset::new(42);
    let mut results = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut rt = Runtime::open_with_opts(
            &dir,
            BackendKind::Reference,
            Some(Parallelism::new(threads)),
        )
        .unwrap();
        assert_eq!(rt.parallelism(), threads);
        let meta = rt.manifest.model("cif10").unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(42));
        let wbits = vec![5u8; meta.w_channels];
        let abits = vec![4u8; meta.a_channels];
        let runner = ModelRunner::new(meta, params).unwrap();
        let res = runner
            .eval_config(&mut rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 3)
            .unwrap();
        results.push(res);
    }
    for res in &results[1..] {
        assert_eq!(
            res.accuracy.to_bits(),
            results[0].accuracy.to_bits(),
            "accuracy diverged: {} vs {}",
            res.accuracy,
            results[0].accuracy
        );
        assert_eq!(
            res.loss.to_bits(),
            results[0].loss.to_bits(),
            "loss diverged: {} vs {}",
            res.loss,
            results[0].loss
        );
        assert_eq!(res.images, results[0].images);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Whole-job determinism across thread counts: a search `JobReport` (which
/// funnels every episode through the parallel eval path) serializes to the
/// same bytes at 1 and 3 threads.
#[test]
fn search_report_is_byte_identical_across_thread_counts() {
    let dir = temp_dir("par_search");
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        let spec = JobSpec::pretrain("cif10").steps(3).build().unwrap();
        coord.run(&spec).unwrap();
    }
    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Channel)
        .episodes(2)
        .warmup(1)
        .eval_batches(2)
        .seed(9)
        .build()
        .unwrap();
    let mut jsons = Vec::new();
    for threads in [1usize, 3] {
        let mut coord = Coordinator::open_with_opts(
            &dir,
            Some(BackendKind::Reference),
            Some(Parallelism::new(threads)),
        )
        .unwrap();
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0;
        jsons.push(report.to_json().to_string());
    }
    assert_eq!(jsons[0], jsons[1], "thread count leaked into the JobReport");
    std::fs::remove_dir_all(&dir).ok();
}

/// Cross-backend numerics smoke test (opt-in lane): identical params →
/// eval accuracy/loss agree between the reference interpreter and PJRT
/// within float-reassociation tolerance.
#[test]
fn cross_backend_eval_accuracy_agrees() {
    if std::env::var("AUTOQ_REQUIRE_ARTIFACTS").is_err() {
        return; // PJRT lane not requested; reference-only CI stays green
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.json").exists(),
        "AUTOQ_REQUIRE_ARTIFACTS=1 but AOT artifacts not built (run `make artifacts`)"
    );
    let mut rt_ref = Runtime::open_with(&dir, BackendKind::Reference).unwrap();
    let mut rt_pjrt = Runtime::open_with(&dir, BackendKind::Pjrt).unwrap();

    let meta_ref = rt_ref.manifest.model("cif10").unwrap().clone();
    let meta_pjrt = rt_pjrt.manifest.model("cif10").unwrap().clone();
    let params = ParamStore::init(&meta_ref.params, &mut Rng::new(42));
    let runner_ref = ModelRunner::new(meta_ref, params.clone()).unwrap();
    let runner_pjrt = ModelRunner::new(meta_pjrt, params).unwrap();

    let data = SynthDataset::new(42);
    for (wb, ab) in [(32u8, 32u8), (5, 4)] {
        let wbits = vec![wb; runner_ref.meta.w_channels];
        let abits = vec![ab; runner_ref.meta.a_channels];
        let a = runner_ref
            .eval_config(&mut rt_ref, Mode::Quant, &wbits, &abits, &data, Split::Val, 1)
            .unwrap();
        let b = runner_pjrt
            .eval_config(&mut rt_pjrt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1)
            .unwrap();
        assert!(
            (a.accuracy - b.accuracy).abs() <= 0.02,
            "accuracy diverged at {wb}w/{ab}a: reference {} vs pjrt {}",
            a.accuracy,
            b.accuracy
        );
        assert!(
            (a.loss - b.loss).abs() <= 0.05 * (1.0 + b.loss.abs()),
            "loss diverged at {wb}w/{ab}a: reference {} vs pjrt {}",
            a.loss,
            b.loss
        );
    }
}

/// Cross-backend `train_step` agreement (opt-in PJRT lane): one SGD step
/// from identical params yields matching losses and parameters that stay
/// within float-reassociation tolerance elementwise.
#[test]
fn cross_backend_train_step_agrees() {
    if std::env::var("AUTOQ_REQUIRE_ARTIFACTS").is_err() {
        return; // PJRT lane not requested; reference-only CI stays green
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rt_ref = Runtime::open_with(&dir, BackendKind::Reference).unwrap();
    let mut rt_pjrt = Runtime::open_with(&dir, BackendKind::Pjrt).unwrap();

    let meta_ref = rt_ref.manifest.model("cif10").unwrap().clone();
    let meta_pjrt = rt_pjrt.manifest.model("cif10").unwrap().clone();
    let params = ParamStore::init(&meta_ref.params, &mut Rng::new(42));
    let mut runner_ref = ModelRunner::new(meta_ref, params.clone()).unwrap();
    let mut runner_pjrt = ModelRunner::new(meta_pjrt, params).unwrap();

    let data = SynthDataset::new(42);
    let wbits = vec![6u8; runner_ref.meta.w_channels];
    let abits = vec![5u8; runner_ref.meta.a_channels];
    let batch = data.batch(Split::Train, 0, runner_ref.meta.train_batch);
    for step in 0..2u64 {
        let l_ref = runner_ref
            .train_step(&mut rt_ref, Mode::Quant, &batch, &wbits, &abits, 0.01)
            .unwrap();
        let l_pjrt = runner_pjrt
            .train_step(&mut rt_pjrt, Mode::Quant, &batch, &wbits, &abits, 0.01)
            .unwrap();
        assert!(
            (l_ref - l_pjrt).abs() <= 0.05 * (1.0 + l_pjrt.abs()),
            "step {step} loss diverged: reference {l_ref} vs pjrt {l_pjrt}"
        );
    }
    for (i, (a, b)) in runner_ref
        .params
        .tensors
        .iter()
        .zip(&runner_pjrt.params.tensors)
        .enumerate()
    {
        let max_diff = a
            .data
            .iter()
            .zip(&b.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff <= 1e-2, "param {i} diverged after 2 steps: max |Δ| = {max_diff}");
    }
}

/// Cross-backend DDPG update agreement (opt-in PJRT lane): same-seeded
/// agents fed the same replay sample stay within tolerance on losses and
/// on the post-update policy.
#[test]
fn cross_backend_ddpg_update_agrees() {
    if std::env::var("AUTOQ_REQUIRE_ARTIFACTS").is_err() {
        return; // PJRT lane not requested; reference-only CI stays green
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rt_ref = Runtime::open_with(&dir, BackendKind::Reference).unwrap();
    let mut rt_pjrt = Runtime::open_with(&dir, BackendKind::Pjrt).unwrap();

    let meta_ref = rt_ref.manifest.agent(16).unwrap().clone();
    let meta_pjrt = rt_pjrt.manifest.agent(16).unwrap().clone();
    let s_dim = meta_ref.s_dim;
    let upd_batch = meta_ref.upd_batch;
    let mut ag_ref = DdpgAgent::new(meta_ref, DdpgHyper::default(), &mut Rng::new(7));
    let mut ag_pjrt = DdpgAgent::new(meta_pjrt, DdpgHyper::default(), &mut Rng::new(7));

    // Identical replay contents on both sides.
    let mut replay_rng = Rng::new(11);
    let mut replay = ReplayBuffer::new(2 * upd_batch);
    for _ in 0..2 * upd_batch {
        let s: Vec<f32> = (0..s_dim).map(|_| replay_rng.f32()).collect();
        let s2: Vec<f32> = (0..s_dim).map(|_| replay_rng.f32()).collect();
        replay.push(Transition {
            s,
            a: replay_rng.f32() * 32.0,
            r: replay_rng.f32() - 0.5,
            s2,
            done: replay_rng.below(8) == 0,
        });
    }
    // Same sampling seed → the update sees the same minibatch.
    ag_ref.update(&mut rt_ref, &replay, &mut Rng::new(13)).unwrap();
    ag_pjrt.update(&mut rt_pjrt, &replay, &mut Rng::new(13)).unwrap();
    assert!(
        (ag_ref.last_critic_loss - ag_pjrt.last_critic_loss).abs()
            <= 0.05 * (1.0 + ag_pjrt.last_critic_loss.abs()),
        "critic loss diverged: reference {} vs pjrt {}",
        ag_ref.last_critic_loss,
        ag_pjrt.last_critic_loss
    );
    assert!(
        (ag_ref.last_actor_loss - ag_pjrt.last_actor_loss).abs()
            <= 0.05 * (1.0 + ag_pjrt.last_actor_loss.abs()),
        "actor loss diverged: reference {} vs pjrt {}",
        ag_ref.last_actor_loss,
        ag_pjrt.last_actor_loss
    );
    // The updated policies must agree on fresh states.
    let mut state_rng = Rng::new(17);
    let n = 4;
    let states: Vec<f32> = (0..n * s_dim).map(|_| state_rng.f32()).collect();
    let mu_ref = ag_ref.act(&mut rt_ref, &states, n).unwrap();
    let mu_pjrt = ag_pjrt.act(&mut rt_pjrt, &states, n).unwrap();
    for (i, (a, b)) in mu_ref.iter().zip(&mu_pjrt).enumerate() {
        assert!((a - b).abs() <= 0.05 * (1.0 + b.abs()), "action {i}: {a} vs {b}");
    }
}
