//! Determinism and cross-backend agreement.
//!
//! * The same `JobSpec` + seed through two freshly-opened `Coordinator`s
//!   yields byte-identical `JobReport` JSON (wall-clock `secs` zeroed —
//!   the only intentionally non-deterministic field).
//! * With `AUTOQ_REQUIRE_ARTIFACTS=1` (the opt-in PJRT lane), the
//!   reference interpreter and the PJRT backend agree on eval
//!   accuracy/loss within tolerance for identical parameters.

use std::path::{Path, PathBuf};

use autoq::coordinator::{Coordinator, JobSpec};
use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::{BackendKind, Runtime};
use autoq::search::{Granularity, Protocol};
use autoq::util::rng::Rng;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("autoq_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

#[test]
fn same_jobspec_and_seed_yield_byte_identical_reports() {
    let dir = temp_dir("determinism");

    // Seed the artifact dir with deterministic pretrained params once, so
    // both search runs load the same persisted weights.
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        let spec = JobSpec::pretrain("cif10").steps(4).build().unwrap();
        coord.run(&spec).unwrap();
    }

    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Channel)
        .episodes(2)
        .warmup(1)
        .eval_batches(1)
        .seed(5)
        .build()
        .unwrap();

    // Two independent coordinators — fresh runtime, fresh runner cache —
    // model a process restart.
    let mut jsons = Vec::new();
    for _ in 0..2 {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0; // wall-clock is the one legitimately varying field
        jsons.push(report.to_json().to_string());
    }
    assert_eq!(jsons[0], jsons[1], "JobReport JSON must be byte-identical");
    // Sanity: the report actually carries a searched config.
    assert!(jsons[0].contains("\"wbits\""));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pretrain_then_eval_is_deterministic_across_coordinators() {
    let dir = temp_dir("det_eval");
    let run = || -> String {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        let spec = JobSpec::pretrain("cif10").steps(3).persist(false).build().unwrap();
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0;
        report.to_json().to_string()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "pretrain reports must replay bit-identically");
    std::fs::remove_dir_all(&dir).ok();
}

/// Cross-backend numerics smoke test (opt-in lane): identical params →
/// eval accuracy/loss agree between the reference interpreter and PJRT
/// within float-reassociation tolerance.
#[test]
fn cross_backend_eval_accuracy_agrees() {
    if std::env::var("AUTOQ_REQUIRE_ARTIFACTS").is_err() {
        return; // PJRT lane not requested; reference-only CI stays green
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.json").exists(),
        "AUTOQ_REQUIRE_ARTIFACTS=1 but AOT artifacts not built (run `make artifacts`)"
    );
    let mut rt_ref = Runtime::open_with(&dir, BackendKind::Reference).unwrap();
    let mut rt_pjrt = Runtime::open_with(&dir, BackendKind::Pjrt).unwrap();

    let meta_ref = rt_ref.manifest.model("cif10").unwrap().clone();
    let meta_pjrt = rt_pjrt.manifest.model("cif10").unwrap().clone();
    let params = ParamStore::init(&meta_ref.params, &mut Rng::new(42));
    let runner_ref = ModelRunner::new(meta_ref, params.clone()).unwrap();
    let runner_pjrt = ModelRunner::new(meta_pjrt, params).unwrap();

    let data = SynthDataset::new(42);
    for (wb, ab) in [(32u8, 32u8), (5, 4)] {
        let wbits = vec![wb; runner_ref.meta.w_channels];
        let abits = vec![ab; runner_ref.meta.a_channels];
        let a = runner_ref
            .eval_config(&mut rt_ref, Mode::Quant, &wbits, &abits, &data, Split::Val, 1)
            .unwrap();
        let b = runner_pjrt
            .eval_config(&mut rt_pjrt, Mode::Quant, &wbits, &abits, &data, Split::Val, 1)
            .unwrap();
        assert!(
            (a.accuracy - b.accuracy).abs() <= 0.02,
            "accuracy diverged at {wb}w/{ab}a: reference {} vs pjrt {}",
            a.accuracy,
            b.accuracy
        );
        assert!(
            (a.loss - b.loss).abs() <= 0.05 * (1.0 + b.loss.abs()),
            "loss diverged at {wb}w/{ab}a: reference {} vs pjrt {}",
            a.loss,
            b.loss
        );
    }
}
