//! Byte-identity between the in-process `reference` backend and the
//! multi-process `shard` backend, plus the crash-replay contract — over
//! both transports (subprocess stdio, TCP loopback) and both wire
//! encodings (JSON, binary).
//!
//! The shard determinism rule (DESIGN.md §Sharded backend): every worker
//! runs the same pure reference interpreter, both codecs preserve f32 bit
//! patterns, and chunk results merge in input order — so every result
//! below must match the reference backend **bit for bit** at 1, 2 and 4
//! workers, whatever the transport or encoding.
//!
//! Worker binary: the test harness points `$AUTOQ_WORKER_EXE` at the
//! `autoq` binary Cargo builds for integration tests — the tests' own
//! executable is the libtest harness, not a shard worker.

use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::OnceLock;

use autoq::coordinator::{Coordinator, JobSpec, Sweep};
use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::shard::{Encoding, ShardClient};
use autoq::runtime::{BackendKind, Parallelism, Runtime, RuntimeOpts, Value};
use autoq::search::{run_search, Granularity, Protocol, SearchConfig};
use autoq::util::rng::Rng;

/// Point the shard client at the real `autoq` binary (once per process).
///
/// Ordering contract: every test in this binary calls `worker_exe()` (or
/// `open_rt`, which does) as its **first** action, so every environment
/// read in this process happens-after the single `set_var` below — the
/// `OnceLock` blocks late arrivals until the first caller's init (and its
/// `set_var`) completes, which is what makes the process-global mutation
/// safe under libtest's parallel test threads.
fn worker_exe() -> PathBuf {
    static EXE: OnceLock<PathBuf> = OnceLock::new();
    EXE.get_or_init(|| {
        let exe = PathBuf::from(env!("CARGO_BIN_EXE_autoq"));
        std::env::set_var("AUTOQ_WORKER_EXE", &exe);
        exe
    })
    .clone()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("autoq_shard_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Open a runtime on `kind`, with `workers` processes when sharded.
fn open_rt(dir: &Path, kind: BackendKind, workers: usize) -> Runtime {
    worker_exe();
    let opts = RuntimeOpts {
        threads: Some(Parallelism::new(2)),
        shard_workers: Some(workers),
        ..Default::default()
    };
    Runtime::open_full(dir, kind, opts).expect("runtime open")
}

/// A live `autoq worker --listen` process on the loopback interface.
/// Readiness is synced by parsing the "listening on" line the worker
/// prints (and flushes) once bound, so `--listen 127.0.0.1:0` callers
/// learn the resolved port before any client dials in.
struct TcpWorker {
    child: Child,
    addr: String,
}

impl TcpWorker {
    fn spawn(exe: &Path, listen: &str) -> TcpWorker {
        let mut child = Command::new(exe)
            .arg("worker")
            .arg("--listen")
            .arg(listen)
            .arg("--threads")
            .arg("1")
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn tcp worker");
        let stdout = child.stdout.take().expect("stdout piped");
        let mut lines = std::io::BufReader::new(stdout).lines();
        let addr = loop {
            let line = lines
                .next()
                .expect("worker exited before announcing its address")
                .expect("read worker stdout");
            if let Some(rest) = line.strip_prefix("autoq worker listening on ") {
                break rest.trim().to_string();
            }
        };
        TcpWorker { child, addr }
    }

    /// SIGKILL the worker and reap it — the mid-run "machine fell over".
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for TcpWorker {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Shard-backend opts for a given transport/encoding cell: `hosts` empty
/// means local subprocesses, else pure-TCP (zero local slots, passed
/// explicitly so `$AUTOQ_SHARD_WORKERS` in CI cannot re-add them).
fn shard_opts(workers: usize, hosts: Vec<String>, enc: Encoding) -> RuntimeOpts {
    let local = if hosts.is_empty() { workers } else { 0 };
    RuntimeOpts {
        threads: Some(Parallelism::new(2)),
        shard_workers: Some(local),
        shard_hosts: Some(hosts),
        shard_encoding: Some(enc),
    }
}

/// Synthesize valid inputs for `artifact` straight from the builtin
/// manifest spec — codec and fan-out don't care that the data is random.
fn synth_batches(artifact: &str, sets: usize, seed: u64) -> Vec<Vec<Value>> {
    let manifest = autoq::runtime::reference::builtin_manifest();
    let spec = manifest.artifact(artifact).unwrap().clone();
    let mut rng = Rng::new(seed);
    (0..sets)
        .map(|_| {
            spec.inputs
                .iter()
                .map(|t| {
                    let data = (0..t.elems()).map(|_| rng.f32() - 0.5).collect();
                    Value::f32(t.shape.clone(), data)
                })
                .collect()
        })
        .collect()
}

/// Assert two exec_batch results carry identical f32 bit patterns.
fn assert_bits_equal(got: &[Vec<Value>], want: &[Vec<Value>], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: set count changed");
    for (i, (g_set, w_set)) in got.iter().zip(want).enumerate() {
        assert_eq!(g_set.len(), w_set.len(), "{what}: batch {i} arity changed");
        for (g, w) in g_set.iter().zip(w_set) {
            let (g, w) = (g.as_f32().unwrap(), w.as_f32().unwrap());
            assert_eq!(g.shape, w.shape, "{what}: batch {i} shape changed");
            let diverged = g.data.iter().zip(&w.data).any(|(a, b)| a.to_bits() != b.to_bits());
            assert!(!diverged, "{what}: batch {i} bytes changed");
        }
    }
}

/// `EvalResult` bits must match the reference backend at every worker
/// count — including with more batches than workers (chunked fan-out) and
/// fewer (idle workers).
#[test]
fn eval_is_byte_identical_to_reference_at_1_2_4_workers() {
    let dir = temp_dir("eval");
    let data = SynthDataset::new(42);
    let eval = |rt: &mut Runtime, batches: usize| {
        let meta = rt.manifest.model("cif10").unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(42));
        let wbits = vec![5u8; meta.w_channels];
        let abits = vec![4u8; meta.a_channels];
        let runner = ModelRunner::new(meta, params).unwrap();
        runner
            .eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, batches)
            .unwrap()
    };
    let mut rt_ref = open_rt(&dir, BackendKind::Reference, 1);
    for batches in [1usize, 3] {
        let want = eval(&mut rt_ref, batches);
        for workers in [1usize, 2, 4] {
            let mut rt = open_rt(&dir, BackendKind::Shard, workers);
            let got = eval(&mut rt, batches);
            assert_eq!(
                got.accuracy.to_bits(),
                want.accuracy.to_bits(),
                "accuracy diverged at {workers} workers / {batches} batches: {} vs {}",
                got.accuracy,
                want.accuracy
            );
            assert_eq!(
                got.loss.to_bits(),
                want.loss.to_bits(),
                "loss diverged at {workers} workers / {batches} batches"
            );
            assert_eq!(got.images, want.images);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Whole `JobReport` JSONs from the `Coordinator` must be byte-identical
/// between `--backend reference` and `--backend shard` at 1/2/4 workers.
/// (Network granularity keeps the agent traffic out of this matrix test;
/// the channel-granularity search below exercises DDPG act/update over
/// the wire.)
#[test]
fn search_job_reports_are_byte_identical_at_1_2_4_workers() {
    let dir = temp_dir("search");
    worker_exe();
    // Seed pretrained params once so every run loads the same bytes.
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        coord.run(&JobSpec::pretrain("cif10").steps(3).build().unwrap()).unwrap();
    }
    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Network(5))
        .eval_batches(2)
        .seed(11)
        .build()
        .unwrap();
    let run = |backend: BackendKind, workers: usize| {
        let opts = RuntimeOpts {
            threads: Some(Parallelism::new(2)),
            shard_workers: Some(workers),
            ..Default::default()
        };
        let mut coord = Coordinator::open_full(&dir, Some(backend), opts).unwrap();
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0; // wall clock is the one legitimately varying field
        report.to_json().to_string()
    };
    let want = run(BackendKind::Reference, 1);
    assert!(want.contains("\"wbits\""), "sanity: report carries a config");
    for workers in [1usize, 2, 4] {
        let got = run(BackendKind::Shard, workers);
        assert_eq!(got, want, "JobReport JSON diverged at {workers} worker(s)");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Channel-granularity search drives the full agent loop — DDPG act and
/// the 58-input update — through the wire codec.  `llc_updates_div` is
/// raised so the test ships a bounded number of (megabyte-sized) update
/// round-trips; byte-identity is checked on the complete `SearchResult`
/// surface at the 2-worker point.
#[test]
fn channel_search_with_agent_traffic_matches_reference() {
    let dir = temp_dir("channel");
    let run = |rt: &mut Runtime| {
        let meta = rt.manifest.model("cif10").unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(5));
        let runner = ModelRunner::new(meta, params).unwrap();
        let data = SynthDataset::new(7);
        let mut cfg = SearchConfig::quick(
            Mode::Quant,
            Protocol::resource_constrained(5.0),
            Granularity::Channel,
        );
        cfg.episodes = 2;
        cfg.warmup = 1;
        cfg.eval_batches = 1;
        cfg.seed = 3;
        cfg.llc_updates_div = 1 << 20; // one LLC update per episode
        run_search(rt, &runner, &data, &cfg).unwrap()
    };
    let want = run(&mut open_rt(&dir, BackendKind::Reference, 1));
    let got = run(&mut open_rt(&dir, BackendKind::Shard, 2));
    assert_eq!(got.best.wbits, want.best.wbits, "searched weight bits diverged");
    assert_eq!(got.best.abits, want.best.abits, "searched activation bits diverged");
    assert_eq!(got.best.reward.to_bits(), want.best.reward.to_bits(), "reward bits diverged");
    assert_eq!(got.best.accuracy.to_bits(), want.best.accuracy.to_bits());
    assert_eq!(got.history.len(), want.history.len());
    for (g, w) in got.history.iter().zip(&want.history) {
        assert_eq!(g.reward.to_bits(), w.reward.to_bits(), "episode {} diverged", w.episode);
        assert_eq!(g.accuracy.to_bits(), w.accuracy.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// `Sweep` on the shard backend: every cell's report must match the
/// reference sweep byte for byte (outer cell workers × inner worker
/// processes composing under one budget).
#[test]
fn sweep_reports_are_byte_identical_between_backends() {
    let dir = temp_dir("sweep");
    worker_exe();
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        coord.run(&JobSpec::pretrain("cif10").steps(3).build().unwrap()).unwrap();
    }
    let run = |backend: BackendKind, workers: usize, out: &str| {
        let sweep = Sweep {
            protocols: vec![Protocol::resource_constrained(5.0), Protocol::accuracy_guaranteed()],
            granularities: vec![Granularity::Network(4)],
            eval_batches: 2,
            base_seed: 21,
            workers: 2,
            out_dir: Some(dir.join(out)),
            backend: Some(backend),
            threads: Some(Parallelism::new(1)),
            shard_workers: Some(workers),
            ..Sweep::default()
        };
        let result = sweep.run(&dir).unwrap();
        assert!(result.failures.is_empty(), "sweep failures: {:?}", result.failures);
        result
            .reports
            .into_iter()
            .map(|mut r| {
                r.secs = 0.0;
                r.to_json().to_string()
            })
            .collect::<Vec<_>>()
    };
    let want = run(BackendKind::Reference, 1, "ref");
    assert_eq!(want.len(), 2);
    for workers in [1usize, 2] {
        let got = run(BackendKind::Shard, workers, &format!("shard{workers}"));
        assert_eq!(got, want, "sweep reports diverged at {workers} shard worker(s)");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-replay: kill one worker between dispatches, then assert the next
/// batch replays onto a respawned worker and the merged result is
/// unchanged — and that exactly one restart happened.
#[test]
fn killed_worker_is_respawned_and_the_batch_replayed_unchanged() {
    let exe = worker_exe();
    let client = ShardClient::new(exe, 2);
    client.set_total_threads(2);

    let values = synth_batches("ddpg_act_s16", 6, 123);
    let batches: Vec<Vec<&Value>> =
        values.iter().map(|set| set.iter().collect()).collect();

    let baseline = client.exec_batch("ddpg_act_s16", &batches).unwrap();
    assert_eq!(baseline.len(), batches.len());
    assert_eq!(client.restarts(), 0, "healthy run must not restart anything");

    client.kill_worker(0);
    let replayed = client.exec_batch("ddpg_act_s16", &batches).unwrap();
    assert_eq!(client.restarts(), 1, "exactly the killed worker must restart");
    assert_bits_equal(&replayed, &baseline, "crash replay");
}

/// The transport × encoding matrix: subprocess and TCP-loopback pools, in
/// JSON and binary, at 1/2/4 workers, must all reproduce the reference
/// backend's `EvalResult` bit for bit.  The four listening workers are
/// spawned once and re-dialed per cell — a client `Drop` ends its TCP
/// *session*, not the worker, so reuse also exercises session turnover.
#[test]
fn eval_is_byte_identical_across_transports_and_encodings() {
    let dir = temp_dir("matrix");
    let exe = worker_exe();
    let data = SynthDataset::new(42);
    let eval = |rt: &mut Runtime| {
        let meta = rt.manifest.model("cif10").unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(42));
        let wbits = vec![5u8; meta.w_channels];
        let abits = vec![4u8; meta.a_channels];
        let runner = ModelRunner::new(meta, params).unwrap();
        runner.eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, 3).unwrap()
    };
    let want = eval(&mut open_rt(&dir, BackendKind::Reference, 1));

    let fleet: Vec<TcpWorker> =
        (0..4).map(|_| TcpWorker::spawn(&exe, "127.0.0.1:0")).collect();
    for enc in [Encoding::Json, Encoding::Binary] {
        for workers in [1usize, 2, 4] {
            for tcp in [false, true] {
                let hosts = if tcp {
                    fleet[..workers].iter().map(|w| w.addr.clone()).collect()
                } else {
                    Vec::new()
                };
                let label = format!(
                    "{} / {} / {workers} worker(s)",
                    if tcp { "tcp" } else { "subprocess" },
                    enc.as_str()
                );
                let opts = shard_opts(workers, hosts, enc);
                let mut rt = Runtime::open_full(&dir, BackendKind::Shard, opts)
                    .expect("shard runtime open");
                let got = eval(&mut rt);
                assert_eq!(
                    got.accuracy.to_bits(),
                    want.accuracy.to_bits(),
                    "accuracy diverged at {label}"
                );
                assert_eq!(got.loss.to_bits(), want.loss.to_bits(), "loss diverged at {label}");
                assert_eq!(got.images, want.images, "image count diverged at {label}");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Whole search `JobReport` JSONs across the three transport/encoding
/// combinations the CI lanes pin: subprocess/JSON, subprocess/binary and
/// TCP-loopback/binary must all emit the reference report byte for byte.
#[test]
fn search_job_reports_match_across_transport_and_encoding() {
    let dir = temp_dir("search_matrix");
    let exe = worker_exe();
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        coord.run(&JobSpec::pretrain("cif10").steps(3).build().unwrap()).unwrap();
    }
    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Network(5))
        .eval_batches(2)
        .seed(11)
        .build()
        .unwrap();
    let run = |backend: Option<BackendKind>, opts: RuntimeOpts| {
        let mut coord = Coordinator::open_full(&dir, backend, opts).unwrap();
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0; // wall clock is the one legitimately varying field
        report.to_json().to_string()
    };
    let ref_opts = RuntimeOpts { threads: Some(Parallelism::new(2)), ..Default::default() };
    let want = run(Some(BackendKind::Reference), ref_opts);

    let fleet: Vec<TcpWorker> = (0..2).map(|_| TcpWorker::spawn(&exe, "127.0.0.1:0")).collect();
    let hosts: Vec<String> = fleet.iter().map(|w| w.addr.clone()).collect();
    let combos = [
        ("subprocess/json", shard_opts(2, Vec::new(), Encoding::Json)),
        ("subprocess/binary", shard_opts(2, Vec::new(), Encoding::Binary)),
        ("tcp/binary", shard_opts(2, hosts, Encoding::Binary)),
    ];
    for (label, opts) in combos {
        let got = run(Some(BackendKind::Shard), opts);
        assert_eq!(got, want, "JobReport JSON diverged on {label}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Mid-run TCP worker death: SIGKILL the remote worker, bring a
/// replacement up on the **same** port, and assert the next batch rides
/// the reconnect-and-replay path to an unchanged result with exactly one
/// restart — the remote twin of the subprocess crash test above.
#[test]
fn killed_tcp_worker_is_reconnected_and_the_batch_replayed_unchanged() {
    let exe = worker_exe();
    let mut first = TcpWorker::spawn(&exe, "127.0.0.1:0");
    let addr = first.addr.clone();
    let client = ShardClient::with_opts(exe.clone(), 0, vec![addr.clone()], Encoding::Binary);

    let values = synth_batches("ddpg_act_s16", 6, 123);
    let batches: Vec<Vec<&Value>> = values.iter().map(|set| set.iter().collect()).collect();

    let baseline = client.exec_batch("ddpg_act_s16", &batches).unwrap();
    assert_eq!(client.restarts(), 0, "healthy run must not reconnect anything");

    // The worker machine "falls over" and comes back on the same address
    // (std's TCP bind sets SO_REUSEADDR on Unix, so the port is reusable
    // immediately); the client only finds out mid-request.
    first.kill();
    let _second = TcpWorker::spawn(&exe, &addr);

    let replayed = client.exec_batch("ddpg_act_s16", &batches).unwrap();
    assert_eq!(client.restarts(), 1, "exactly one reconnect must happen");
    assert_bits_equal(&replayed, &baseline, "tcp reconnect replay");
}

/// Session-level failure (our socket dies, the worker survives): the
/// client must reconnect to the *same* worker and replay.  Also proves a
/// listening worker outlives its sessions.
#[test]
fn dropped_tcp_session_reconnects_to_the_same_worker() {
    let exe = worker_exe();
    let worker = TcpWorker::spawn(&exe, "127.0.0.1:0");
    let client = ShardClient::with_opts(exe.clone(), 0, vec![worker.addr.clone()], Encoding::Binary);

    let values = synth_batches("ddpg_act_s16", 4, 321);
    let batches: Vec<Vec<&Value>> = values.iter().map(|set| set.iter().collect()).collect();

    let baseline = client.exec_batch("ddpg_act_s16", &batches).unwrap();
    client.kill_worker(0); // shuts down the session socket, not the worker
    let replayed = client.exec_batch("ddpg_act_s16", &batches).unwrap();
    assert_eq!(client.restarts(), 1, "exactly one reconnect must happen");
    assert_bits_equal(&replayed, &baseline, "session reconnect replay");
}
