//! Byte-identity between the in-process `reference` backend and the
//! multi-process `shard` backend, plus the crash-replay contract.
//!
//! The shard determinism rule (DESIGN.md §Sharded backend): every worker
//! process runs the same pure reference interpreter, the wire codec
//! preserves f32 bit patterns, and chunk results merge in input order —
//! so every result below must match the reference backend **bit for
//! bit** at 1, 2 and 4 worker processes.
//!
//! Worker binary: the test harness points `$AUTOQ_WORKER_EXE` at the
//! `autoq` binary Cargo builds for integration tests — the tests' own
//! executable is the libtest harness, not a shard worker.

use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use autoq::coordinator::{Coordinator, JobSpec, Sweep};
use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::models::{ModelRunner, ParamStore};
use autoq::runtime::shard::ShardClient;
use autoq::runtime::{BackendKind, Parallelism, Runtime, RuntimeOpts, Value};
use autoq::search::{run_search, Granularity, Protocol, SearchConfig};
use autoq::util::rng::Rng;

/// Point the shard client at the real `autoq` binary (once per process).
///
/// Ordering contract: every test in this binary calls `worker_exe()` (or
/// `open_rt`, which does) as its **first** action, so every environment
/// read in this process happens-after the single `set_var` below — the
/// `OnceLock` blocks late arrivals until the first caller's init (and its
/// `set_var`) completes, which is what makes the process-global mutation
/// safe under libtest's parallel test threads.
fn worker_exe() -> PathBuf {
    static EXE: OnceLock<PathBuf> = OnceLock::new();
    EXE.get_or_init(|| {
        let exe = PathBuf::from(env!("CARGO_BIN_EXE_autoq"));
        std::env::set_var("AUTOQ_WORKER_EXE", &exe);
        exe
    })
    .clone()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("autoq_shard_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Open a runtime on `kind`, with `workers` processes when sharded.
fn open_rt(dir: &Path, kind: BackendKind, workers: usize) -> Runtime {
    worker_exe();
    let opts = RuntimeOpts {
        threads: Some(Parallelism::new(2)),
        shard_workers: Some(workers),
    };
    Runtime::open_full(dir, kind, opts).expect("runtime open")
}

/// `EvalResult` bits must match the reference backend at every worker
/// count — including with more batches than workers (chunked fan-out) and
/// fewer (idle workers).
#[test]
fn eval_is_byte_identical_to_reference_at_1_2_4_workers() {
    let dir = temp_dir("eval");
    let data = SynthDataset::new(42);
    let eval = |rt: &mut Runtime, batches: usize| {
        let meta = rt.manifest.model("cif10").unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(42));
        let wbits = vec![5u8; meta.w_channels];
        let abits = vec![4u8; meta.a_channels];
        let runner = ModelRunner::new(meta, params).unwrap();
        runner
            .eval_config(rt, Mode::Quant, &wbits, &abits, &data, Split::Val, batches)
            .unwrap()
    };
    let mut rt_ref = open_rt(&dir, BackendKind::Reference, 1);
    for batches in [1usize, 3] {
        let want = eval(&mut rt_ref, batches);
        for workers in [1usize, 2, 4] {
            let mut rt = open_rt(&dir, BackendKind::Shard, workers);
            let got = eval(&mut rt, batches);
            assert_eq!(
                got.accuracy.to_bits(),
                want.accuracy.to_bits(),
                "accuracy diverged at {workers} workers / {batches} batches: {} vs {}",
                got.accuracy,
                want.accuracy
            );
            assert_eq!(
                got.loss.to_bits(),
                want.loss.to_bits(),
                "loss diverged at {workers} workers / {batches} batches"
            );
            assert_eq!(got.images, want.images);
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Whole `JobReport` JSONs from the `Coordinator` must be byte-identical
/// between `--backend reference` and `--backend shard` at 1/2/4 workers.
/// (Network granularity keeps the agent traffic out of this matrix test;
/// the channel-granularity search below exercises DDPG act/update over
/// the wire.)
#[test]
fn search_job_reports_are_byte_identical_at_1_2_4_workers() {
    let dir = temp_dir("search");
    worker_exe();
    // Seed pretrained params once so every run loads the same bytes.
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        coord.run(&JobSpec::pretrain("cif10").steps(3).build().unwrap()).unwrap();
    }
    let spec = JobSpec::search("cif10")
        .mode(Mode::Quant)
        .protocol(Protocol::resource_constrained(5.0))
        .granularity(Granularity::Network(5))
        .eval_batches(2)
        .seed(11)
        .build()
        .unwrap();
    let run = |backend: BackendKind, workers: usize| {
        let opts = RuntimeOpts {
            threads: Some(Parallelism::new(2)),
            shard_workers: Some(workers),
        };
        let mut coord = Coordinator::open_full(&dir, Some(backend), opts).unwrap();
        let mut report = coord.run(&spec).unwrap();
        report.secs = 0.0; // wall clock is the one legitimately varying field
        report.to_json().to_string()
    };
    let want = run(BackendKind::Reference, 1);
    assert!(want.contains("\"wbits\""), "sanity: report carries a config");
    for workers in [1usize, 2, 4] {
        let got = run(BackendKind::Shard, workers);
        assert_eq!(got, want, "JobReport JSON diverged at {workers} worker(s)");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Channel-granularity search drives the full agent loop — DDPG act and
/// the 58-input update — through the wire codec.  `llc_updates_div` is
/// raised so the test ships a bounded number of (megabyte-sized) update
/// round-trips; byte-identity is checked on the complete `SearchResult`
/// surface at the 2-worker point.
#[test]
fn channel_search_with_agent_traffic_matches_reference() {
    let dir = temp_dir("channel");
    let run = |rt: &mut Runtime| {
        let meta = rt.manifest.model("cif10").unwrap().clone();
        let params = ParamStore::init(&meta.params, &mut Rng::new(5));
        let runner = ModelRunner::new(meta, params).unwrap();
        let data = SynthDataset::new(7);
        let mut cfg = SearchConfig::quick(
            Mode::Quant,
            Protocol::resource_constrained(5.0),
            Granularity::Channel,
        );
        cfg.episodes = 2;
        cfg.warmup = 1;
        cfg.eval_batches = 1;
        cfg.seed = 3;
        cfg.llc_updates_div = 1 << 20; // one LLC update per episode
        run_search(rt, &runner, &data, &cfg).unwrap()
    };
    let want = run(&mut open_rt(&dir, BackendKind::Reference, 1));
    let got = run(&mut open_rt(&dir, BackendKind::Shard, 2));
    assert_eq!(got.best.wbits, want.best.wbits, "searched weight bits diverged");
    assert_eq!(got.best.abits, want.best.abits, "searched activation bits diverged");
    assert_eq!(got.best.reward.to_bits(), want.best.reward.to_bits(), "reward bits diverged");
    assert_eq!(got.best.accuracy.to_bits(), want.best.accuracy.to_bits());
    assert_eq!(got.history.len(), want.history.len());
    for (g, w) in got.history.iter().zip(&want.history) {
        assert_eq!(g.reward.to_bits(), w.reward.to_bits(), "episode {} diverged", w.episode);
        assert_eq!(g.accuracy.to_bits(), w.accuracy.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// `Sweep` on the shard backend: every cell's report must match the
/// reference sweep byte for byte (outer cell workers × inner worker
/// processes composing under one budget).
#[test]
fn sweep_reports_are_byte_identical_between_backends() {
    let dir = temp_dir("sweep");
    worker_exe();
    {
        let mut coord = Coordinator::open_with(&dir, Some(BackendKind::Reference)).unwrap();
        coord.run(&JobSpec::pretrain("cif10").steps(3).build().unwrap()).unwrap();
    }
    let run = |backend: BackendKind, workers: usize, out: &str| {
        let sweep = Sweep {
            protocols: vec![Protocol::resource_constrained(5.0), Protocol::accuracy_guaranteed()],
            granularities: vec![Granularity::Network(4)],
            eval_batches: 2,
            base_seed: 21,
            workers: 2,
            out_dir: Some(dir.join(out)),
            backend: Some(backend),
            threads: Some(Parallelism::new(1)),
            shard_workers: Some(workers),
            ..Sweep::default()
        };
        let result = sweep.run(&dir).unwrap();
        assert!(result.failures.is_empty(), "sweep failures: {:?}", result.failures);
        result
            .reports
            .into_iter()
            .map(|mut r| {
                r.secs = 0.0;
                r.to_json().to_string()
            })
            .collect::<Vec<_>>()
    };
    let want = run(BackendKind::Reference, 1, "ref");
    assert_eq!(want.len(), 2);
    for workers in [1usize, 2] {
        let got = run(BackendKind::Shard, workers, &format!("shard{workers}"));
        assert_eq!(got, want, "sweep reports diverged at {workers} shard worker(s)");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-replay: kill one worker between dispatches, then assert the next
/// batch replays onto a respawned worker and the merged result is
/// unchanged — and that exactly one restart happened.
#[test]
fn killed_worker_is_respawned_and_the_batch_replayed_unchanged() {
    let exe = worker_exe();
    let client = ShardClient::new(exe, 2);
    client.set_total_threads(2);

    // Synthesize valid inputs straight from the builtin manifest spec —
    // the codec and fan-out don't care that the network is random.
    let manifest = autoq::runtime::reference::builtin_manifest();
    let spec = manifest.artifact("ddpg_act_s16").unwrap().clone();
    let mut rng = Rng::new(123);
    let values: Vec<Vec<Value>> = (0..6)
        .map(|_| {
            spec.inputs
                .iter()
                .map(|t| {
                    let data = (0..t.elems()).map(|_| rng.f32() - 0.5).collect();
                    Value::f32(t.shape.clone(), data)
                })
                .collect()
        })
        .collect();
    let batches: Vec<Vec<&Value>> =
        values.iter().map(|set| set.iter().collect()).collect();

    let baseline = client.exec_batch(&spec.name, &batches).unwrap();
    assert_eq!(baseline.len(), batches.len());
    assert_eq!(client.restarts(), 0, "healthy run must not restart anything");

    client.kill_worker(0);
    let replayed = client.exec_batch(&spec.name, &batches).unwrap();
    assert_eq!(client.restarts(), 1, "exactly the killed worker must restart");
    assert_eq!(replayed.len(), baseline.len());
    for (i, (got, want)) in replayed.iter().zip(&baseline).enumerate() {
        assert_eq!(got.len(), want.len(), "batch {i} arity changed");
        for (g, w) in got.iter().zip(want) {
            let (g, w) = (g.as_f32().unwrap(), w.as_f32().unwrap());
            assert_eq!(g.shape, w.shape);
            let diverged = g
                .data
                .iter()
                .zip(&w.data)
                .any(|(a, b)| a.to_bits() != b.to_bits());
            assert!(!diverged, "batch {i} bytes changed after the crash replay");
        }
    }
}
