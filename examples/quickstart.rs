//! Quickstart: the coordinator job API in ~50 lines.
//!
//!   1. open a `Coordinator` over the AOT artifacts (it owns the PJRT
//!      runtime and pre-trains zoo models on first use),
//!   2. evaluate the fp32 reference, run a short accuracy-guaranteed
//!      channel-level search,
//!   3. fine-tune the best config and simulate FPGA deployment —
//!      each step one validated `JobSpec`.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use autoq::coordinator::{Coordinator, JobOutcome, JobSpec};
use autoq::cost::Mode;
use autoq::search::{Granularity, Protocol};

fn main() -> anyhow::Result<()> {
    autoq::util::logging::init();
    let mut coord = Coordinator::open_default()?;

    // Full-precision reference accuracy.
    let fp = coord.run(&JobSpec::eval("cif10").batches(2).build()?)?;
    if let JobOutcome::Eval(e) = &fp.outcome {
        println!("fp32 accuracy: {:.4}", e.accuracy);
    }

    // Short accuracy-guaranteed channel-level search (paper protocol §3.3);
    // the best config is written out for the follow-up jobs.
    let cfg_path = std::env::temp_dir().join("autoq_quickstart_best.json");
    let search = coord.run(
        &JobSpec::search("cif10")
            .mode(Mode::Quant)
            .protocol(Protocol::accuracy_guaranteed())
            .granularity(Granularity::Channel)
            .episodes(12)
            .warmup(4)
            .out(cfg_path.clone())
            .build()?,
    )?;
    let JobOutcome::Search { best, .. } = &search.outcome else { unreachable!() };
    println!(
        "searched: acc={:.4} avg weight bits={:.2} avg act bits={:.2} (logic ops at {:.2}% of fp32)",
        best.accuracy,
        best.avg_wbits,
        best.avg_abits,
        best.cost.norm_logic() * 100.0
    );

    // Fine-tune the searched configuration (recovers quantization loss).
    let ft = coord.run(&JobSpec::finetune("cif10", cfg_path.clone()).steps(40).build()?)?;
    if let JobOutcome::Train { final_eval, .. } = &ft.outcome {
        println!("fine-tuned accuracy: {:.4}", final_eval.accuracy);
    }

    // Deploy on both simulated FPGA accelerator templates.
    let sim = coord.run(&JobSpec::sim("cif10").config(cfg_path).build()?)?;
    if let JobOutcome::Sim(rows) = &sim.outcome {
        for r in rows {
            println!(
                "{:<9} accelerator: {:>8.1} fps, {:>7.3} mJ/inference, utilization {:.2}",
                r.arch, r.fps, r.energy_mj, r.utilization
            );
        }
    }
    Ok(())
}
