//! Quickstart: the public API in ~60 lines.
//!
//!   1. open the PJRT runtime over the AOT artifacts,
//!   2. load (or pre-train) the 7-conv CIFAR CNN,
//!   3. run a short accuracy-guaranteed channel-level search,
//!   4. fine-tune the best config and simulate FPGA deployment.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use autoq::cost::Mode;
use autoq::data::synth::SynthDataset;
use autoq::repro::common::runner_for;
use autoq::runtime::Runtime;
use autoq::search::{run_search, Granularity, Protocol, SearchConfig};
use autoq::sim::{Arch, FpgaSim};

fn main() -> anyhow::Result<()> {
    autoq::util::logging::init();
    let mut rt = Runtime::open_default()?;
    let runner = runner_for(&mut rt, "cif10")?;
    let data = SynthDataset::new(42);

    // Full-precision reference accuracy.
    let fp = runner.eval_fp32(&mut rt, &data, autoq::data::Split::Val, 2)?;
    println!("fp32 accuracy: {:.4}", fp.accuracy);

    // Short accuracy-guaranteed channel-level search (paper protocol §3.3).
    let mut cfg = SearchConfig::quick(
        Mode::Quant,
        Protocol::accuracy_guaranteed(),
        Granularity::Channel,
    );
    cfg.episodes = 12;
    cfg.warmup = 4;
    let res = run_search(&mut rt, &runner, &data, &cfg)?;
    let best = &res.best;
    println!(
        "searched: acc={:.4} avg weight bits={:.2} avg act bits={:.2} (logic ops at {:.2}% of fp32)",
        best.accuracy,
        best.avg_wbits,
        best.avg_abits,
        best.cost.norm_logic() * 100.0
    );

    // Fine-tune the searched configuration (recovers quantization loss).
    let mut ft_runner = runner_for(&mut rt, "cif10")?;
    let tc = autoq::finetune::TrainConfig::finetune(
        Mode::Quant,
        best.wbits.clone(),
        best.abits.clone(),
        40,
    );
    let rep = autoq::finetune::train(&mut rt, &mut ft_runner, &data, &tc)?;
    println!("fine-tuned accuracy: {:.4}", rep.final_eval.accuracy);

    // Deploy on both simulated FPGA accelerator templates.
    for arch in [Arch::Temporal, Arch::Spatial] {
        let sim = FpgaSim::new(arch, Mode::Quant);
        let r = sim.run(&runner.meta.layers, &best.wbits, &best.abits);
        println!(
            "{:<9} accelerator: {:>8.1} fps, {:>7.3} mJ/inference, utilization {:.2}",
            arch.as_str(),
            r.fps,
            r.energy_j * 1e3,
            r.utilization
        );
    }
    Ok(())
}
