//! End-to-end system driver (the EXPERIMENTS.md §E2E run): proves all three
//! layers compose on a real small workload, driven entirely through the
//! coordinator job API.
//!
//!   1. PRE-TRAIN the 7-conv CIFAR CNN from scratch (seeded init) through
//!      the AOT'd fused train-step, logging the loss curve.
//!   2. SEARCH per-channel bit-widths with the hierarchical DRL agent under
//!      both paper protocols (RC + AG).
//!   3. FINE-TUNE the AG winner and report the recovered accuracy.
//!   4. DEPLOY on both FPGA simulators and audit §3.4 storage overhead.
//!
//! Run: `cargo run --release --example end_to_end [episodes]`

use autoq::coordinator::{Coordinator, JobOutcome, JobSpec};
use autoq::cost::Mode;
use autoq::search::{Granularity, Protocol};

fn main() -> anyhow::Result<()> {
    autoq::util::logging::init();
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(25);
    let t0 = std::time::Instant::now();
    let mut coord = Coordinator::open_default()?;

    // ---- 1. pre-train from scratch ----------------------------------------
    // persist(false): this is a demo run — keep any saved trained params.
    println!("== stage 1: pre-training cif10 (fresh params) ==");
    let pre =
        coord.run(&JobSpec::pretrain("cif10").steps(250).seed(0xE2E).persist(false).build()?)?;
    let JobOutcome::Train { curve, final_eval, .. } = &pre.outcome else { unreachable!() };
    println!("loss curve (step, loss):");
    for (s, l) in curve {
        println!("  {s:>5} {l:.4}");
    }
    let fp_acc = final_eval.accuracy;
    println!("fp32 val accuracy: {fp_acc:.4} ({:.1}s)", pre.secs);

    // ---- 2. hierarchical searches ------------------------------------------
    println!("\n== stage 2: channel-level searches ({episodes} episodes each) ==");
    let mut results = Vec::new();
    for protocol in [Protocol::resource_constrained(5.0), Protocol::accuracy_guaranteed()] {
        let cfg_path = std::env::temp_dir().join(format!("autoq_e2e_{}.json", protocol.tag()));
        let report = coord.run(
            &JobSpec::search("cif10")
                .mode(Mode::Quant)
                .protocol(protocol)
                .granularity(Granularity::Channel)
                .episodes(episodes)
                .warmup(episodes / 3)
                .out(cfg_path.clone())
                .build()?,
        )?;
        let JobOutcome::Search { best, .. } = &report.outcome else { unreachable!() };
        println!(
            "{:<22} best: acc={:.4} wbits={:.2} abits={:.2} norm_logic={:.4} ({:.0}s)",
            protocol.name(),
            best.accuracy,
            best.avg_wbits,
            best.avg_abits,
            best.cost.norm_logic(),
            report.secs
        );
        results.push((protocol, cfg_path, report));
    }

    // ---- 3. fine-tune the accuracy-guaranteed winner ------------------------
    println!("\n== stage 3: fine-tuning the AG configuration ==");
    let (_, ag_cfg, ag_report) = &results[1];
    let JobOutcome::Search { best: ag_best, .. } = &ag_report.outcome else { unreachable!() };
    let ft = coord.run(&JobSpec::finetune("cif10", ag_cfg.clone()).steps(80).build()?)?;
    let JobOutcome::Train { final_eval: ft_eval, .. } = &ft.outcome else { unreachable!() };
    println!(
        "AG config: searched acc {:.4} -> fine-tuned {:.4} (Δ vs fp32: {:+.2}%)",
        ag_best.accuracy,
        ft_eval.accuracy,
        (ft_eval.accuracy - fp_acc) * 100.0
    );

    // ---- 4. deployment ------------------------------------------------------
    println!("\n== stage 4: FPGA deployment + storage audit ==");
    let meta = coord.manifest().model("cif10")?.clone();
    for (protocol, cfg_path, report) in &results {
        let sim = coord.run(&JobSpec::sim("cif10").config(cfg_path.clone()).build()?)?;
        if let JobOutcome::Sim(rows) = &sim.outcome {
            for r in rows {
                println!(
                    "{:<22} {:<9}: {:>8.1} fps {:>8.3} mJ util={:.2}",
                    protocol.name(),
                    r.arch,
                    r.fps,
                    r.energy_mj,
                    r.utilization
                );
            }
        }
        let JobOutcome::Search { best, .. } = &report.outcome else { continue };
        let audit = autoq::quant::audit(&meta.layers, &best.wbits, &best.abits);
        println!(
            "{:<22} storage: {:.1} KB weights + {:.2} KB bit-configs ({:.3}% overhead)",
            protocol.name(),
            audit.weight_bytes as f64 / 1024.0,
            audit.config_bytes as f64 / 1024.0,
            audit.overhead * 100.0
        );
    }

    println!("\nend-to-end driver finished in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
