//! End-to-end system driver (the EXPERIMENTS.md §E2E run): proves all three
//! layers compose on a real small workload.
//!
//!   1. PRE-TRAIN the 7-conv CIFAR CNN from scratch through the AOT'd
//!      fused train-step (L2 fwd/bwd built on the L1 Pallas quantizers),
//!      logging the loss curve.
//!   2. SEARCH per-channel bit-widths with the hierarchical DRL agent under
//!      both paper protocols (RC + AG).
//!   3. FINE-TUNE the AG winner and report the recovered accuracy.
//!   4. DEPLOY on both FPGA simulators and audit §3.4 storage overhead.
//!
//! Run: `cargo run --release --example end_to_end [episodes]`

use autoq::cost::Mode;
use autoq::data::synth::{Split, SynthDataset};
use autoq::finetune::TrainConfig;
use autoq::models::ModelRunner;
use autoq::runtime::Runtime;
use autoq::search::{run_search, Granularity, Protocol, SearchConfig};
use autoq::sim::{Arch, FpgaSim};
use autoq::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    autoq::util::logging::init();
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(25);
    let t0 = std::time::Instant::now();
    let mut rt = Runtime::open_default()?;
    let data = SynthDataset::new(42);

    // ---- 1. pre-train from scratch ----------------------------------------
    println!("== stage 1: pre-training cif10 (fresh params) ==");
    let meta = rt.manifest.model("cif10")?.clone();
    let mut runner = ModelRunner::init(meta, &mut Rng::new(0xE2E));
    let cfg = TrainConfig::pretrain(250);
    let rep = autoq::finetune::train(&mut rt, &mut runner, &data, &cfg)?;
    println!("loss curve (step, loss):");
    for (s, l) in &rep.curve {
        println!("  {s:>5} {l:.4}");
    }
    let fp = runner.eval_fp32(&mut rt, &data, Split::Val, 2)?;
    println!("fp32 val accuracy: {:.4} ({:.1}s)", fp.accuracy, rep.secs);

    // ---- 2. hierarchical searches ------------------------------------------
    println!("\n== stage 2: channel-level searches ({episodes} episodes each) ==");
    let mut results = Vec::new();
    for protocol in [Protocol::resource_constrained(5.0), Protocol::accuracy_guaranteed()] {
        let mut scfg = SearchConfig::quick(Mode::Quant, protocol, Granularity::Channel);
        scfg.episodes = episodes;
        scfg.warmup = episodes / 3;
        let res = run_search(&mut rt, &runner, &data, &scfg)?;
        println!(
            "{:<22} best: acc={:.4} wbits={:.2} abits={:.2} norm_logic={:.4} ({:.0}s)",
            protocol.name(),
            res.best.accuracy,
            res.best.avg_wbits,
            res.best.avg_abits,
            res.best.cost.norm_logic(),
            res.secs
        );
        results.push((protocol, res));
    }

    // ---- 3. fine-tune the accuracy-guaranteed winner ------------------------
    println!("\n== stage 3: fine-tuning the AG configuration ==");
    let ag = &results[1].1.best;
    let tc = TrainConfig::finetune(Mode::Quant, ag.wbits.clone(), ag.abits.clone(), 80);
    let ft = autoq::finetune::train(&mut rt, &mut runner, &data, &tc)?;
    println!(
        "AG config: searched acc {:.4} -> fine-tuned {:.4} (Δ vs fp32: {:+.2}%)",
        ag.accuracy,
        ft.final_eval.accuracy,
        (ft.final_eval.accuracy - fp.accuracy) * 100.0
    );

    // ---- 4. deployment ------------------------------------------------------
    println!("\n== stage 4: FPGA deployment + storage audit ==");
    for (protocol, res) in &results {
        for arch in [Arch::Temporal, Arch::Spatial] {
            let sim = FpgaSim::new(arch, Mode::Quant);
            let r = sim.run(&runner.meta.layers, &res.best.wbits, &res.best.abits);
            println!(
                "{:<22} {:<9}: {:>8.1} fps {:>8.3} mJ util={:.2}",
                protocol.name(),
                arch.as_str(),
                r.fps,
                r.energy_j * 1e3,
                r.utilization
            );
        }
        let audit = autoq::quant::audit(&runner.meta.layers, &res.best.wbits, &res.best.abits);
        println!(
            "{:<22} storage: {:.1} KB weights + {:.2} KB bit-configs ({:.3}% overhead)",
            protocol.name(),
            audit.weight_bytes as f64 / 1024.0,
            audit.config_bytes as f64 / 1024.0,
            audit.overhead * 100.0
        );
    }

    println!("\nend-to-end driver finished in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
