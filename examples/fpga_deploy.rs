//! FPGA deployment study (the intro's mobile-device scenario): take the
//! depthwise MobileNetV2-style model, search it at every granularity, and
//! compare quantized vs binarized deployment on the spatial and temporal
//! accelerator templates — the decision a mobile hardware developer makes
//! with AutoQ's output (paper §4.5).
//!
//! Run: `cargo run --release --example fpga_deploy [episodes]`

use autoq::cost::Mode;
use autoq::data::synth::SynthDataset;
use autoq::repro::common::runner_for;
use autoq::runtime::Runtime;
use autoq::search::{run_search, Granularity, Protocol, SearchConfig};
use autoq::sim::{Arch, FpgaSim};

fn main() -> anyhow::Result<()> {
    autoq::util::logging::init();
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(15);
    let mut rt = Runtime::open_default()?;
    let runner = runner_for(&mut rt, "monet")?;
    let data = SynthDataset::new(42);
    let meta = runner.meta.clone();

    println!(
        "{:<6} {:<6} {:>7} {:>6} {:>6} {:>10} {:>10} {:>10} {:>10}",
        "mode", "gran", "acc", "wbits", "abits", "fps(temp)", "fps(spat)", "mJ(temp)", "mJ(spat)"
    );
    for mode in [Mode::Quant, Mode::Binar] {
        for gran in [Granularity::Network(5), Granularity::Layer, Granularity::Channel] {
            let mut cfg =
                SearchConfig::quick(mode, Protocol::resource_constrained(5.0), gran);
            cfg.episodes = episodes;
            cfg.warmup = episodes / 3;
            let res = run_search(&mut rt, &runner, &data, &cfg)?;
            let b = &res.best;
            let t = FpgaSim::new(Arch::Temporal, mode).run(&meta.layers, &b.wbits, &b.abits);
            let s = FpgaSim::new(Arch::Spatial, mode).run(&meta.layers, &b.wbits, &b.abits);
            println!(
                "{:<6} {:<6} {:>7.4} {:>6.2} {:>6.2} {:>10.1} {:>10.1} {:>10.3} {:>10.3}",
                mode.as_str(),
                gran.tag(),
                b.accuracy,
                b.avg_wbits,
                b.avg_abits,
                t.fps,
                s.fps,
                t.energy_j * 1e3,
                s.energy_j * 1e3
            );
        }
    }
    println!("\n(paper shape: C > L > N on fps; binar faster but less accurate; temporal wins on -C)");
    Ok(())
}
