//! FPGA deployment study (the intro's mobile-device scenario): take the
//! depthwise MobileNetV2-style model, sweep it at every granularity in both
//! modes across two worker threads via the coordinator's `Sweep` scheduler,
//! and compare quantized vs binarized deployment on the spatial and
//! temporal accelerator templates — the decision a mobile hardware
//! developer makes with AutoQ's output (paper §4.5).
//!
//! Run: `cargo run --release --example fpga_deploy [episodes]`

use autoq::coordinator::{Coordinator, JobKind, JobOutcome, Sweep};
use autoq::cost::Mode;
use autoq::search::{Granularity, Protocol};
use autoq::sim::{Arch, FpgaSim};

fn main() -> anyhow::Result<()> {
    autoq::util::logging::init();
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(15);
    let dir = Coordinator::default_dir();
    // Model metadata comes from the runtime's manifest (builtin on the
    // reference backend, artifacts/manifest.json on PJRT).
    let meta = Coordinator::open(&dir)?.manifest().model("monet")?.clone();

    let sweep = Sweep {
        models: vec!["monet".to_string()],
        modes: vec![Mode::Quant, Mode::Binar],
        protocols: vec![Protocol::resource_constrained(5.0)],
        granularities: vec![Granularity::Network(5), Granularity::Layer, Granularity::Channel],
        episodes,
        warmup: episodes / 3,
        workers: 2,
        ..Sweep::default()
    };
    let result = sweep.run(&dir)?;
    anyhow::ensure!(result.failures.is_empty(), "sweep failures: {:?}", result.failures);

    println!(
        "{:<6} {:<6} {:>7} {:>6} {:>6} {:>10} {:>10} {:>10} {:>10}",
        "mode", "gran", "acc", "wbits", "abits", "fps(temp)", "fps(spat)", "mJ(temp)", "mJ(spat)"
    );
    for report in &result.reports {
        let JobKind::Search(p) = &report.spec.kind else { continue };
        let JobOutcome::Search { best, .. } = &report.outcome else { continue };
        let t = FpgaSim::new(Arch::Temporal, p.mode).run(&meta.layers, &best.wbits, &best.abits);
        let s = FpgaSim::new(Arch::Spatial, p.mode).run(&meta.layers, &best.wbits, &best.abits);
        println!(
            "{:<6} {:<6} {:>7.4} {:>6.2} {:>6.2} {:>10.1} {:>10.1} {:>10.3} {:>10.3}",
            p.mode.as_str(),
            p.granularity.tag(),
            best.accuracy,
            best.avg_wbits,
            best.avg_abits,
            t.fps,
            s.fps,
            t.energy_j * 1e3,
            s.energy_j * 1e3
        );
    }
    println!("\n(paper shape: C > L > N on fps; binar faster but less accurate; temporal wins on -C)");
    Ok(())
}
