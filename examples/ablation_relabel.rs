//! Ablation: HIRO goal relabeling on vs off (the design choice DESIGN.md
//! calls out — the "Correcting High level Training" machinery of §3.2).
//! Runs matched-seed channel searches through the coordinator job API
//! (`JobSpec::search(..).relabel(false)`) and compares the learning curves.
//!
//! Run: `cargo run --release --example ablation_relabel [episodes] [runs]`

use autoq::coordinator::{Coordinator, JobOutcome, JobSpec};
use autoq::cost::Mode;
use autoq::search::{Granularity, Protocol};
use autoq::util::stats;

fn main() -> anyhow::Result<()> {
    autoq::util::logging::init();
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let runs: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let mut coord = Coordinator::open_default()?;

    let mut curves: Vec<(bool, Vec<f64>)> = Vec::new();
    for relabel in [true, false] {
        let mut acc = vec![0.0f64; episodes];
        let mut best_rewards = Vec::new();
        for run in 0..runs {
            let report = coord.run(
                &JobSpec::search("cif10")
                    .mode(Mode::Quant)
                    .protocol(Protocol::accuracy_guaranteed())
                    .granularity(Granularity::Channel)
                    .episodes(episodes)
                    .warmup(episodes / 3)
                    .relabel(relabel)
                    .seed(1 + run as u64 * 57)
                    .build()?,
            )?;
            let JobOutcome::Search { best, history } = &report.outcome else { unreachable!() };
            for (i, st) in history.iter().enumerate() {
                acc[i] += st.reward / runs as f64;
            }
            best_rewards.push(best.reward);
        }
        println!(
            "relabel={relabel:<5} mean best reward over {runs} runs: {:.4}",
            stats::mean(&best_rewards)
        );
        curves.push((relabel, acc));
    }

    println!("\nepisode  reward(relabel=on)  reward(relabel=off)");
    for ep in 0..episodes {
        println!("{ep:<8} {:>18.4} {:>19.4}", curves[0].1[ep], curves[1].1[ep]);
    }
    Ok(())
}
