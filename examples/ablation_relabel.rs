//! Ablation: HIRO goal relabeling on vs off (the design choice DESIGN.md
//! calls out — the "Correcting High level Training" machinery of §3.2).
//! Runs matched-seed channel searches and compares the learning curves.
//!
//! Run: `cargo run --release --example ablation_relabel [episodes] [runs]`

use autoq::cost::Mode;
use autoq::data::synth::SynthDataset;
use autoq::repro::common::runner_for;
use autoq::runtime::Runtime;
use autoq::search::{run_search, Granularity, Protocol, SearchConfig};
use autoq::util::stats;

fn main() -> anyhow::Result<()> {
    autoq::util::logging::init();
    let episodes: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let runs: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(2);
    let mut rt = Runtime::open_default()?;
    let runner = runner_for(&mut rt, "cif10")?;
    let data = SynthDataset::new(42);

    let mut curves: Vec<(bool, Vec<f64>)> = Vec::new();
    for relabel in [true, false] {
        let mut acc = vec![0.0f64; episodes];
        let mut best_rewards = Vec::new();
        for run in 0..runs {
            let mut cfg = SearchConfig::quick(
                Mode::Quant,
                Protocol::accuracy_guaranteed(),
                Granularity::Channel,
            );
            cfg.episodes = episodes;
            cfg.warmup = episodes / 3;
            cfg.relabel = relabel;
            cfg.seed = 1 + run as u64 * 57;
            let res = run_search(&mut rt, &runner, &data, &cfg)?;
            for (i, st) in res.history.iter().enumerate() {
                acc[i] += st.reward / runs as f64;
            }
            best_rewards.push(res.best.reward);
        }
        println!(
            "relabel={relabel:<5} mean best reward over {runs} runs: {:.4}",
            stats::mean(&best_rewards)
        );
        curves.push((relabel, acc));
    }

    println!("\nepisode  reward(relabel=on)  reward(relabel=off)");
    for ep in 0..episodes {
        println!("{ep:<8} {:>18.4} {:>19.4}", curves[0].1[ep], curves[1].1[ep]);
    }
    Ok(())
}
